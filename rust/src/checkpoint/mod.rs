//! Binary checkpoint + step-log formats for the distributed trainer's
//! crash/rejoin story.
//!
//! Checkpoint layout (little-endian):
//!   magic "CMZ1" | preset_len u32 | preset bytes | step u64 | n_bufs u32 |
//!   per buf: name_len u32 | name | len u64 | f32 data |
//!   crc32 u32 over everything after the magic
//!
//! Step-log layout (magic "CMZW"): an **append-only write-ahead log** of
//! self-delimiting cells, each individually CRC-framed:
//!
//! ```text
//!   "CMZW" | cell | cell | ...
//!   cell   = kind u8 | payload | crc32 u32 over (kind | payload)
//!   kind 1 = step record   (28-byte [`StepRecord`]: seed, g, theta, eta, beta)
//!   kind 2 = consensus hash (t u64 | params_hash u64 — a tripwire round at
//!            step t agreed on this hash; lets a restarted leader re-arm the
//!            divergence check without re-evaluating anything)
//! ```
//!
//! Because the ZO update is a pure function of the start state and the step
//! record stream (direction regenerated from `seed`, update applied with
//! the broadcast `g`), a worker's exact `(x, m)` at step `t` is
//! reproducible by replaying records `0..t` with **zero** function
//! evaluations. This is the implemented rejoin path (see
//! `coordinator::cluster` and `ZoWorker::replay`) — and, since the log is a
//! WAL, the implemented *leader restart* path too (`conmezo leader
//! --resume`).
//!
//! The leader appends one cell per step through an open [`StepLogWriter`]
//! (O(1) bytes/step — the old CMZL format rewrote all `t` records under a
//! single trailing CRC on every save, O(t) bytes/step, and one torn write
//! lost the whole file). Durability is governed by [`FsyncPolicy`]:
//! `every-step` (default: fsync before the step's Apply is broadcast, so no
//! worker can ever apply a step the log doesn't hold), `every-N` (amortized;
//! a crash may lose up to N-1 tail records — workers ahead of the recovered
//! log are refused at rejoin and must warm-start from a checkpoint), or
//! `close` (fsync only on shutdown; fastest, test-only).
//!
//! On load ([`load_wal`]) a torn or bit-flipped tail is **recovered, not
//! rejected**: the loader keeps the longest valid prefix of cells, reports
//! how many records it dropped ([`WalRecovery`]), and [`StepLogWriter::resume`]
//! truncates the file back to that prefix before appending. A wrong magic
//! still hard-errors, [`Checkpoint`] files still hard-error on any CRC
//! mismatch, and all length fields stay untrusted (checked arithmetic, so a
//! crafted header errors instead of wrapping into an out-of-bounds panic).
//! Checkpoint snapshots are written through [`crate::util::fs::atomic_write`],
//! so a crash mid-save leaves the previous snapshot intact.

use std::collections::BTreeMap;
use std::io::{Read, Seek, Write};
use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};
use crate::util::fs::atomic_write;

const MAGIC: &[u8; 4] = b"CMZ1";
const WAL_MAGIC: &[u8; 4] = b"CMZW";

/// WAL cell kind: one 28-byte [`StepRecord`].
const WAL_KIND_STEP: u8 = 1;
/// WAL cell kind: a `(t, params_hash)` consensus marker from a tripwire round.
const WAL_KIND_CONSENSUS: u8 = 2;
/// kind + payload + crc32 for a step cell.
pub const WAL_STEP_CELL_BYTES: usize = 1 + STEP_RECORD_BYTES + 4;
/// kind + payload + crc32 for a consensus cell.
pub const WAL_CONSENSUS_CELL_BYTES: usize = 1 + 16 + 4;

fn wal_payload_len(kind: u8) -> Option<usize> {
    match kind {
        WAL_KIND_STEP => Some(STEP_RECORD_BYTES),
        WAL_KIND_CONSENSUS => Some(16),
        _ => None,
    }
}

/// CRC-32 (IEEE) with a lazily built table.
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, t) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *t = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// FNV-1a 64 over the little-endian bytes of a parameter vector: the cheap
/// deterministic hash behind the cluster's divergence tripwire and the
/// rejoin `params_hash` comparison. Identical replicas hash identically on
/// every platform (f32 bit patterns, not values, are hashed).
pub fn params_hash(x: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in x {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Everything needed to reproduce one ZO update without function evals:
/// the direction seed, the aggregated projected gradient, and the hypers
/// the step actually used (theta for the cone mix, eta/beta for the
/// update). 28 bytes on the wire and on disk.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepRecord {
    pub seed: u64,
    pub g: f64,
    pub theta: f32,
    pub eta: f32,
    pub beta: f32,
}

/// Encoded size of a [`StepRecord`].
pub const STEP_RECORD_BYTES: usize = 28;

impl StepRecord {
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend(self.seed.to_le_bytes());
        out.extend(self.g.to_le_bytes());
        out.extend(self.theta.to_le_bytes());
        out.extend(self.eta.to_le_bytes());
        out.extend(self.beta.to_le_bytes());
    }

    /// Decode from exactly [`STEP_RECORD_BYTES`] bytes (caller-validated).
    pub fn decode(b: &[u8]) -> StepRecord {
        debug_assert_eq!(b.len(), STEP_RECORD_BYTES);
        StepRecord {
            seed: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            g: f64::from_le_bytes(b[8..16].try_into().unwrap()),
            theta: f32::from_le_bytes(b[16..20].try_into().unwrap()),
            eta: f32::from_le_bytes(b[20..24].try_into().unwrap()),
            beta: f32::from_le_bytes(b[24..28].try_into().unwrap()),
        }
    }
}

/// The leader's persistent per-step record log (O(1) bytes/step). Record
/// `i` reproduces the update taking step `i` to step `i+1`.
#[derive(Clone, Debug, Default)]
pub struct StepLog {
    pub records: Vec<StepRecord>,
}

impl StepLog {
    pub fn new() -> Self {
        StepLog { records: Vec::new() }
    }

    /// Number of logged steps (= the step the log replays up to).
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Load the records of a CMZW WAL, recovering (not rejecting) a torn
    /// tail. Convenience wrapper over [`load_wal`] for callers that only
    /// want the replayable record stream.
    pub fn load(path: &Path) -> Result<StepLog> {
        Ok(load_wal(path)?.log)
    }
}

/// When the log's durability is paid for: every append, every N appends, or
/// only at close. `every-step` is the default and is what makes the
/// WAL-before-Apply ordering in the leader a real guarantee.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    EveryStep,
    EveryN(u64),
    Close,
}

impl FsyncPolicy {
    /// Parse the CLI knob: `every-step` | `every-N` (e.g. `every-16`) |
    /// `close`.
    pub fn parse(s: &str) -> Result<FsyncPolicy> {
        match s {
            "every-step" => Ok(FsyncPolicy::EveryStep),
            "close" => Ok(FsyncPolicy::Close),
            _ => {
                if let Some(n) = s.strip_prefix("every-") {
                    let n: u64 = n
                        .parse()
                        .map_err(|_| crate::anyhow!("bad fsync policy {s:?}"))?;
                    if n == 0 {
                        bail!("bad fsync policy {s:?}: N must be >= 1");
                    }
                    return Ok(if n == 1 { FsyncPolicy::EveryStep } else { FsyncPolicy::EveryN(n) });
                }
                bail!("bad fsync policy {s:?} (want every-step | every-N | close)")
            }
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsyncPolicy::EveryStep => write!(f, "every-step"),
            FsyncPolicy::EveryN(n) => write!(f, "every-{n}"),
            FsyncPolicy::Close => write!(f, "close"),
        }
    }
}

/// Result of loading a CMZW WAL: the longest valid prefix of cells, plus an
/// account of what (if anything) was torn off the tail.
#[derive(Clone, Debug, Default)]
pub struct WalRecovery {
    /// Replayable step records from the valid prefix.
    pub log: StepLog,
    /// The latest `(t, params_hash)` consensus cell in the valid prefix.
    pub consensus: Option<(u64, u64)>,
    /// Byte offset (from file start) where the valid prefix ends.
    pub valid_bytes: u64,
    /// Bytes past the valid prefix that were dropped.
    pub dropped_bytes: u64,
    /// Records the dropped tail appears to have held (structural count —
    /// CRC-failed but well-framed cells plus at most one partial cell).
    pub dropped_records: u64,
}

impl WalRecovery {
    /// True when the file carried a torn/corrupt tail that was cut off.
    pub fn truncated(&self) -> bool {
        self.dropped_bytes > 0
    }
}

/// Load a CMZW WAL, keeping the longest valid prefix of cells. A torn or
/// bit-flipped tail is truncated out of the result (and counted), not
/// rejected; a missing/foreign magic is still a hard error.
pub fn load_wal(path: &Path) -> Result<WalRecovery> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut bytes)?;
    if bytes.len() < 4 || &bytes[..4] != WAL_MAGIC {
        bail!("{}: not a CMZW step log", path.display());
    }
    let mut rec = WalRecovery::default();
    let mut i = 4usize;
    // valid prefix: stop at the first cell that is short, unknown-kind, or
    // CRC-inconsistent
    while i < bytes.len() {
        let kind = bytes[i];
        let plen = match wal_payload_len(kind) {
            Some(p) => p,
            None => break,
        };
        let end = match i.checked_add(1 + plen + 4) {
            Some(e) if e <= bytes.len() => e,
            _ => break,
        };
        let cell = &bytes[i..end];
        let stored = u32::from_le_bytes(cell[1 + plen..].try_into().unwrap());
        if crc32(&cell[..1 + plen]) != stored {
            break;
        }
        let payload = &cell[1..1 + plen];
        match kind {
            WAL_KIND_STEP => rec.log.records.push(StepRecord::decode(payload)),
            WAL_KIND_CONSENSUS => {
                rec.consensus = Some((
                    u64::from_le_bytes(payload[0..8].try_into().unwrap()),
                    u64::from_le_bytes(payload[8..16].try_into().unwrap()),
                ));
            }
            _ => unreachable!(),
        }
        i = end;
    }
    rec.valid_bytes = i as u64;
    rec.dropped_bytes = (bytes.len() - i) as u64;
    // best-effort structural count of what the dropped tail held: walk the
    // framing while ignoring CRCs; any trailing partial cell counts as one
    let mut j = i;
    while j < bytes.len() {
        match wal_payload_len(bytes[j]) {
            Some(p) if j + 1 + p + 4 <= bytes.len() => {
                if bytes[j] == WAL_KIND_STEP {
                    rec.dropped_records += 1;
                }
                j += 1 + p + 4;
            }
            _ => {
                rec.dropped_records += 1;
                break;
            }
        }
    }
    Ok(rec)
}

/// An open append-only writer over the CMZW WAL: O(1) bytes per step, one
/// CRC-framed cell per append, fsyncs governed by [`FsyncPolicy`]. Keeps
/// its own append/fsync/byte counters so the caller can surface them in
/// telemetry without the checkpoint layer depending on it.
#[derive(Debug)]
pub struct StepLogWriter {
    file: std::fs::File,
    path: PathBuf,
    policy: FsyncPolicy,
    pending: u64,
    appends: u64,
    fsyncs: u64,
    bytes_written: u64,
}

impl StepLogWriter {
    /// Create a fresh WAL at `path` (truncating any existing file), write
    /// and fsync the magic.
    pub fn create(path: &Path, policy: FsyncPolicy) -> Result<StepLogWriter> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        file.write_all(WAL_MAGIC)?;
        file.sync_all()?;
        Ok(StepLogWriter {
            file,
            path: path.to_path_buf(),
            policy,
            pending: 0,
            appends: 0,
            fsyncs: 0,
            bytes_written: WAL_MAGIC.len() as u64,
        })
    }

    /// Open an existing WAL for appending: recover the longest valid
    /// prefix, physically truncate any torn tail, and position at the end.
    /// A missing file is created fresh (recovery reports zero records).
    pub fn resume(path: &Path, policy: FsyncPolicy) -> Result<(StepLogWriter, WalRecovery)> {
        let len = match std::fs::metadata(path) {
            Ok(m) => m.len(),
            Err(_) => 0,
        };
        if len < WAL_MAGIC.len() as u64 {
            // missing, or a crash hit create() before the magic was durable:
            // nothing recoverable, start fresh
            return Ok((StepLogWriter::create(path, policy)?, WalRecovery::default()));
        }
        let rec = load_wal(path)?;
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        if rec.truncated() {
            file.set_len(rec.valid_bytes)
                .with_context(|| format!("truncating torn tail of {}", path.display()))?;
            file.sync_all()?;
        }
        let mut w = StepLogWriter {
            file,
            path: path.to_path_buf(),
            policy,
            pending: 0,
            appends: 0,
            fsyncs: 0,
            bytes_written: 0,
        };
        w.file.seek(std::io::SeekFrom::End(0))?;
        Ok((w, rec))
    }

    fn append_cell(&mut self, kind: u8, payload: &[u8]) -> Result<()> {
        let mut cell = Vec::with_capacity(1 + payload.len() + 4);
        cell.push(kind);
        cell.extend_from_slice(payload);
        cell.extend(crc32(&cell).to_le_bytes());
        self.file
            .write_all(&cell)
            .with_context(|| format!("appending to {}", self.path.display()))?;
        self.bytes_written += cell.len() as u64;
        self.appends += 1;
        self.pending += 1;
        match self.policy {
            FsyncPolicy::EveryStep => self.sync()?,
            FsyncPolicy::EveryN(n) => {
                if self.pending >= n {
                    self.sync()?;
                }
            }
            FsyncPolicy::Close => {}
        }
        Ok(())
    }

    /// Append one step record (33 bytes on disk).
    pub fn append_step(&mut self, r: &StepRecord) -> Result<()> {
        let mut payload = Vec::with_capacity(STEP_RECORD_BYTES);
        r.encode_into(&mut payload);
        self.append_cell(WAL_KIND_STEP, &payload)
    }

    /// Append a `(t, params_hash)` consensus marker from a tripwire round.
    pub fn append_consensus(&mut self, t: u64, hash: u64) -> Result<()> {
        let mut payload = Vec::with_capacity(16);
        payload.extend(t.to_le_bytes());
        payload.extend(hash.to_le_bytes());
        self.append_cell(WAL_KIND_CONSENSUS, &payload)
    }

    /// Force pending appends to disk now (also the `Close`-policy hook).
    pub fn sync(&mut self) -> Result<()> {
        if self.pending > 0 {
            self.file
                .sync_all()
                .with_context(|| format!("fsyncing {}", self.path.display()))?;
            self.fsyncs += 1;
            self.pending = 0;
        }
        Ok(())
    }

    /// Total cells appended through this writer.
    pub fn appends(&self) -> u64 {
        self.appends
    }

    /// Total fsyncs issued by this writer.
    pub fn fsyncs(&self) -> u64 {
        self.fsyncs
    }

    /// Total bytes written through this writer (incl. magic on create).
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for StepLogWriter {
    fn drop(&mut self) {
        // best-effort: under the `close` / `every-N` policies this is where
        // the tail becomes durable on clean shutdown
        let _ = self.sync();
    }
}

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub preset: String,
    pub step: u64,
    pub buffers: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn new(preset: &str, step: u64) -> Self {
        Checkpoint { preset: preset.to_string(), step, buffers: BTreeMap::new() }
    }

    pub fn put(&mut self, name: &str, data: &[f32]) {
        self.buffers.insert(name.to_string(), data.to_vec());
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.buffers
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| crate::anyhow!("checkpoint missing buffer {name:?}"))
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        p.extend((self.preset.len() as u32).to_le_bytes());
        p.extend(self.preset.as_bytes());
        p.extend(self.step.to_le_bytes());
        p.extend((self.buffers.len() as u32).to_le_bytes());
        for (name, data) in &self.buffers {
            p.extend((name.len() as u32).to_le_bytes());
            p.extend(name.as_bytes());
            p.extend((data.len() as u64).to_le_bytes());
            for v in data {
                p.extend(v.to_le_bytes());
            }
        }
        p
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let payload = self.payload();
        let mut bytes = Vec::with_capacity(4 + payload.len() + 4);
        bytes.extend(MAGIC);
        bytes.extend(&payload);
        bytes.extend(crc32(&payload).to_le_bytes());
        // snapshots are replaced atomically: a crash mid-save leaves the
        // previous checkpoint intact instead of a torn CMZ1
        atomic_write(path, &bytes).with_context(|| format!("saving {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..4] != MAGIC {
            bail!("{}: not a CMZ1 checkpoint", path.display());
        }
        let payload = &bytes[4..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(payload) != stored_crc {
            bail!("{}: CRC mismatch (corrupt checkpoint)", path.display());
        }
        let mut r = Reader { b: payload, i: 0 };
        let plen = r.u32()? as usize;
        let preset = String::from_utf8(r.take(plen)?.to_vec())?;
        let step = r.u64()?;
        let n = r.u32()? as usize;
        let mut buffers = BTreeMap::new();
        for _ in 0..n {
            let nlen = r.u32()? as usize;
            let name = String::from_utf8(r.take(nlen)?.to_vec())?;
            // dlen is untrusted: checked_mul so a crafted u64 errors instead
            // of wrapping `dlen * 4` into a tiny in-bounds read (or a
            // release-mode OOB panic in the old unchecked guard)
            let dlen = r.u64()? as usize;
            let nbytes = dlen
                .checked_mul(4)
                .ok_or_else(|| crate::anyhow!("buffer {name:?} length {dlen} overflows"))?;
            let raw = r.take(nbytes)?;
            let mut data = Vec::with_capacity(dlen);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            buffers.insert(name, data);
        }
        Ok(Checkpoint { preset, step, buffers })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: `self.i + n` with a crafted n wraps in release mode
        // and turns this guard into an out-of-bounds panic — error instead
        let end = match self.i.checked_add(n) {
            Some(e) if e <= self.b.len() => e,
            _ => bail!("truncated checkpoint"),
        };
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("conmezo_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new("tiny", 1234);
        c.put("params", &[1.0, -2.5, 3.25]);
        c.put("momentum", &[0.0; 100]);
        let p = tmpfile("rt.ckpt");
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(l.preset, "tiny");
        assert_eq!(l.step, 1234);
        assert_eq!(l.get("params").unwrap(), &[1.0, -2.5, 3.25]);
        assert_eq!(l.get("momentum").unwrap().len(), 100);
        assert!(l.get("missing").is_err());
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::new("tiny", 1);
        c.put("params", &[1.0; 64]);
        let p = tmpfile("corrupt.ckpt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let mut c = Checkpoint::new("tiny", 1);
        c.put("params", &[1.0; 64]);
        let p = tmpfile("trunc.ckpt");
        c.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmpfile("magic.ckpt");
        std::fs::write(&p, b"NOPE12345678").unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("not a CMZ1"), "{err}");
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: crc32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn crafted_buffer_length_errors_cleanly() {
        // hand-build a CMZ1 file whose single buffer claims dlen =
        // u64::MAX: `dlen * 4` would wrap to 0x...FFFC — the old unchecked
        // reader either OOB-panicked (release) or overflow-panicked
        // (debug); now it must return an error
        let mut payload = Vec::new();
        payload.extend(4u32.to_le_bytes());
        payload.extend(b"tiny");
        payload.extend(7u64.to_le_bytes()); // step
        payload.extend(1u32.to_le_bytes()); // n_bufs
        payload.extend(1u32.to_le_bytes());
        payload.extend(b"x");
        payload.extend(u64::MAX.to_le_bytes()); // crafted dlen
        let mut bytes = Vec::new();
        bytes.extend(MAGIC);
        bytes.extend(&payload);
        bytes.extend(crc32(&payload).to_le_bytes());
        let p = tmpfile("crafted_dlen.ckpt");
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("overflow") || err.contains("truncated"), "{err}");
    }

    #[test]
    fn crafted_name_length_errors_cleanly() {
        // nlen near usize::MAX exercises the checked_add in Reader::take
        let mut payload = Vec::new();
        payload.extend(4u32.to_le_bytes());
        payload.extend(b"tiny");
        payload.extend(7u64.to_le_bytes());
        payload.extend(1u32.to_le_bytes());
        payload.extend(u32::MAX.to_le_bytes()); // crafted nlen
        let mut bytes = Vec::new();
        bytes.extend(MAGIC);
        bytes.extend(&payload);
        bytes.extend(crc32(&payload).to_le_bytes());
        let p = tmpfile("crafted_nlen.ckpt");
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn step_record_roundtrip() {
        let r = StepRecord { seed: 0xABCD, g: -0.125, theta: 1.35, eta: 1e-3, beta: 0.97 };
        let mut buf = Vec::new();
        r.encode_into(&mut buf);
        assert_eq!(buf.len(), STEP_RECORD_BYTES);
        assert_eq!(StepRecord::decode(&buf), r);
    }

    fn synth_record(t: u64) -> StepRecord {
        StepRecord {
            seed: t.wrapping_mul(0x9E3779B97F4A7C15),
            g: (t as f64) * 0.01 - 0.2,
            theta: 1.35,
            eta: 1e-3,
            beta: 0.9 + (t as f32) * 1e-3,
        }
    }

    fn write_wal(name: &str, n: u64) -> (std::path::PathBuf, Vec<StepRecord>) {
        let p = tmpfile(name);
        let mut w = StepLogWriter::create(&p, FsyncPolicy::Close).unwrap();
        let recs: Vec<StepRecord> = (0..n).map(synth_record).collect();
        for r in &recs {
            w.append_step(r).unwrap();
        }
        drop(w);
        (p, recs)
    }

    #[test]
    fn wal_roundtrip_and_consensus() {
        let p = tmpfile("steps.cmzw");
        let mut w = StepLogWriter::create(&p, FsyncPolicy::EveryStep).unwrap();
        for t in 0..50u64 {
            w.append_step(&synth_record(t)).unwrap();
            if t == 24 {
                w.append_consensus(25, 0xDEAD_BEEF_CAFE_F00D).unwrap();
            }
        }
        assert_eq!(w.appends(), 51);
        assert!(w.fsyncs() >= 51, "every-step policy fsyncs per append");
        drop(w);
        let rec = load_wal(&p).unwrap();
        assert_eq!(rec.log.len(), 50);
        assert_eq!(rec.log.records, (0..50).map(synth_record).collect::<Vec<_>>());
        assert_eq!(rec.consensus, Some((25, 0xDEAD_BEEF_CAFE_F00D)));
        assert!(!rec.truncated());
        assert_eq!(rec.dropped_records, 0);
        // StepLog::load convenience wrapper agrees
        assert_eq!(StepLog::load(&p).unwrap().records, rec.log.records);
    }

    #[test]
    fn wal_bytes_per_step_is_constant() {
        // the WAL must cost O(1) bytes per step: cell size is fixed and the
        // file grows by exactly one cell per append across a 100-step run
        // (the old CMZL format rewrote all t records on every save)
        let p = tmpfile("o1.cmzw");
        let mut w = StepLogWriter::create(&p, FsyncPolicy::Close).unwrap();
        let base = w.bytes_written();
        let mut prev = base;
        for t in 0..100u64 {
            w.append_step(&synth_record(t)).unwrap();
            let now = w.bytes_written();
            assert_eq!(now - prev, WAL_STEP_CELL_BYTES as u64, "step {t} wrote O(t) bytes");
            prev = now;
        }
        assert_eq!(w.bytes_written() - base, 100 * WAL_STEP_CELL_BYTES as u64);
        w.sync().unwrap();
        let disk = std::fs::metadata(&p).unwrap().len();
        assert_eq!(disk, 4 + 100 * WAL_STEP_CELL_BYTES as u64);
    }

    #[test]
    fn wal_fsync_policies() {
        let p = tmpfile("fsync.cmzw");
        let mut w = StepLogWriter::create(&p, FsyncPolicy::EveryN(10)).unwrap();
        for t in 0..25u64 {
            w.append_step(&synth_record(t)).unwrap();
        }
        assert_eq!(w.fsyncs(), 2, "25 appends under every-10 = 2 fsyncs");
        w.sync().unwrap();
        assert_eq!(w.fsyncs(), 3, "explicit sync flushes the 5-record tail");
        w.sync().unwrap();
        assert_eq!(w.fsyncs(), 3, "sync with nothing pending is a no-op");

        assert_eq!(FsyncPolicy::parse("every-step").unwrap(), FsyncPolicy::EveryStep);
        assert_eq!(FsyncPolicy::parse("every-1").unwrap(), FsyncPolicy::EveryStep);
        assert_eq!(FsyncPolicy::parse("every-16").unwrap(), FsyncPolicy::EveryN(16));
        assert_eq!(FsyncPolicy::parse("close").unwrap(), FsyncPolicy::Close);
        assert!(FsyncPolicy::parse("every-0").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
        assert_eq!(FsyncPolicy::EveryN(16).to_string(), "every-16");
    }

    #[test]
    fn wal_truncated_mid_record_recovers_prefix() {
        let (p, recs) = write_wal("torn_mid_record.cmzw", 20);
        let full = std::fs::read(&p).unwrap();
        // cut mid-way through the last cell's payload
        let cut = full.len() - WAL_STEP_CELL_BYTES + 10;
        std::fs::write(&p, &full[..cut]).unwrap();
        let rec = load_wal(&p).unwrap();
        assert_eq!(rec.log.records, recs[..19]);
        assert!(rec.truncated());
        assert_eq!(rec.dropped_records, 1);
    }

    #[test]
    fn wal_truncated_mid_crc_recovers_prefix() {
        let (p, recs) = write_wal("torn_mid_crc.cmzw", 20);
        let full = std::fs::read(&p).unwrap();
        // keep kind + payload of the last cell but only 2 of 4 CRC bytes
        let cut = full.len() - 2;
        std::fs::write(&p, &full[..cut]).unwrap();
        let rec = load_wal(&p).unwrap();
        assert_eq!(rec.log.records, recs[..19]);
        assert!(rec.truncated());
        assert_eq!(rec.dropped_records, 1);
    }

    #[test]
    fn wal_corrupt_tail_record_dropped() {
        let (p, recs) = write_wal("corrupt_tail.cmzw", 20);
        let mut full = std::fs::read(&p).unwrap();
        // flip one payload bit inside the final cell: framing stays intact,
        // the per-record CRC catches it, only that record is dropped
        let n = full.len();
        full[n - WAL_STEP_CELL_BYTES + 5] ^= 0x20;
        std::fs::write(&p, &full).unwrap();
        let rec = load_wal(&p).unwrap();
        assert_eq!(rec.log.records, recs[..19]);
        assert_eq!(rec.dropped_bytes, WAL_STEP_CELL_BYTES as u64);
        assert_eq!(rec.dropped_records, 1);
    }

    #[test]
    fn wal_corrupt_middle_drops_suffix() {
        let (p, recs) = write_wal("corrupt_mid.cmzw", 20);
        let mut full = std::fs::read(&p).unwrap();
        // corrupt record 10 of 20: the valid prefix is 0..10 and the
        // structural count sees the 10 well-framed cells behind the tear
        full[4 + 10 * WAL_STEP_CELL_BYTES + 3] ^= 0x80;
        std::fs::write(&p, &full).unwrap();
        let rec = load_wal(&p).unwrap();
        assert_eq!(rec.log.records, recs[..10]);
        assert_eq!(rec.dropped_records, 10);
    }

    #[test]
    fn wal_resume_truncates_tail_and_appends() {
        let (p, recs) = write_wal("resume.cmzw", 20);
        let full = std::fs::read(&p).unwrap();
        let cut = full.len() - 7; // torn tail
        std::fs::write(&p, &full[..cut]).unwrap();
        let (mut w, rec) = StepLogWriter::resume(&p, FsyncPolicy::EveryStep).unwrap();
        assert_eq!(rec.log.len(), 19);
        assert_eq!(rec.dropped_records, 1);
        // the torn bytes are physically gone and appending resumes cleanly
        w.append_step(&synth_record(19)).unwrap();
        w.append_step(&synth_record(20)).unwrap();
        drop(w);
        let rec2 = load_wal(&p).unwrap();
        assert!(!rec2.truncated());
        assert_eq!(rec2.log.len(), 21);
        assert_eq!(rec2.log.records[..19], recs[..19]);
        assert_eq!(rec2.log.records[19], synth_record(19));
    }

    #[test]
    fn wal_resume_missing_file_creates_fresh() {
        let p = tmpfile("resume_fresh.cmzw");
        let _ = std::fs::remove_file(&p);
        let (mut w, rec) = StepLogWriter::resume(&p, FsyncPolicy::Close).unwrap();
        assert_eq!(rec.log.len(), 0);
        assert!(!rec.truncated());
        w.append_step(&synth_record(0)).unwrap();
        drop(w);
        assert_eq!(load_wal(&p).unwrap().log.len(), 1);
    }

    #[test]
    fn wal_wrong_magic_rejected() {
        let p = tmpfile("magic.cmzw");
        std::fs::write(&p, b"NOPE").unwrap();
        let err = load_wal(&p).unwrap_err().to_string();
        assert!(err.contains("not a CMZW"), "{err}");
    }

    #[test]
    fn params_hash_is_deterministic_and_sensitive() {
        let a = vec![1.0f32, -2.5, 3.25, 0.0];
        let b = vec![1.0f32, -2.5, 3.25, 0.0];
        let mut c = a.clone();
        c[3] = f32::from_bits(1); // one-ulp-from-zero flips the hash
        assert_eq!(params_hash(&a), params_hash(&b));
        assert_ne!(params_hash(&a), params_hash(&c));
        // FNV-1a offset basis for the empty input
        assert_eq!(params_hash(&[]), 0xcbf29ce484222325);
    }
}
