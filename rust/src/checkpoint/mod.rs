//! Binary checkpoint + step-log formats for the distributed trainer's
//! crash/rejoin story.
//!
//! Checkpoint layout (little-endian):
//!   magic "CMZ1" | preset_len u32 | preset bytes | step u64 | n_bufs u32 |
//!   per buf: name_len u32 | name | len u64 | f32 data |
//!   crc32 u32 over everything after the magic
//!
//! Step-log layout ([`StepLog`], magic "CMZL"): a flat run of 28-byte
//! [`StepRecord`]s — `(seed, g, theta, eta, beta)` per step — with the same
//! trailing CRC. Because the ZO update is a pure function of the start
//! state and that record stream (direction regenerated from `seed`, update
//! applied with the broadcast `g`), a worker's exact `(x, m)` at step `t`
//! is reproducible by replaying records `0..t` with **zero** function
//! evaluations. This is the implemented rejoin path (see
//! `coordinator::cluster` and `ZoWorker::replay`): the leader persists the
//! log next to its checkpoint, and a (re)joining worker either replays from
//! scratch, or loads a CRC-checked [`Checkpoint`] snapshot and replays only
//! the gap `ckpt.step..t` shipped in a `Replay` message — O(1) bytes per
//! missed step either way.
//!
//! CRCs are checked on load; truncated or bit-flipped files are rejected,
//! and all length fields are treated as untrusted (checked arithmetic, so a
//! crafted header errors instead of wrapping into an out-of-bounds panic).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"CMZ1";
const LOG_MAGIC: &[u8; 4] = b"CMZL";

/// CRC-32 (IEEE) with a lazily built table.
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, t) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *t = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// FNV-1a 64 over the little-endian bytes of a parameter vector: the cheap
/// deterministic hash behind the cluster's divergence tripwire and the
/// rejoin `params_hash` comparison. Identical replicas hash identically on
/// every platform (f32 bit patterns, not values, are hashed).
pub fn params_hash(x: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in x {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Everything needed to reproduce one ZO update without function evals:
/// the direction seed, the aggregated projected gradient, and the hypers
/// the step actually used (theta for the cone mix, eta/beta for the
/// update). 28 bytes on the wire and on disk.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StepRecord {
    pub seed: u64,
    pub g: f64,
    pub theta: f32,
    pub eta: f32,
    pub beta: f32,
}

/// Encoded size of a [`StepRecord`].
pub const STEP_RECORD_BYTES: usize = 28;

impl StepRecord {
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend(self.seed.to_le_bytes());
        out.extend(self.g.to_le_bytes());
        out.extend(self.theta.to_le_bytes());
        out.extend(self.eta.to_le_bytes());
        out.extend(self.beta.to_le_bytes());
    }

    /// Decode from exactly [`STEP_RECORD_BYTES`] bytes (caller-validated).
    pub fn decode(b: &[u8]) -> StepRecord {
        debug_assert_eq!(b.len(), STEP_RECORD_BYTES);
        StepRecord {
            seed: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            g: f64::from_le_bytes(b[8..16].try_into().unwrap()),
            theta: f32::from_le_bytes(b[16..20].try_into().unwrap()),
            eta: f32::from_le_bytes(b[20..24].try_into().unwrap()),
            beta: f32::from_le_bytes(b[24..28].try_into().unwrap()),
        }
    }
}

/// The leader's persistent per-step record log (O(1) bytes/step). Record
/// `i` reproduces the update taking step `i` to step `i+1`.
#[derive(Clone, Debug, Default)]
pub struct StepLog {
    pub records: Vec<StepRecord>,
}

impl StepLog {
    pub fn new() -> Self {
        StepLog { records: Vec::new() }
    }

    /// Number of logged steps (= the step the log replays up to).
    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::with_capacity(8 + self.records.len() * STEP_RECORD_BYTES);
        p.extend((self.records.len() as u64).to_le_bytes());
        for r in &self.records {
            r.encode_into(&mut p);
        }
        p
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let payload = self.payload();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(LOG_MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<StepLog> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..4] != LOG_MAGIC {
            bail!("{}: not a CMZL step log", path.display());
        }
        let payload = &bytes[4..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(payload) != stored_crc {
            bail!("{}: CRC mismatch (corrupt step log)", path.display());
        }
        let mut r = Reader { b: payload, i: 0 };
        let n = r.u64()? as usize;
        let need = n
            .checked_mul(STEP_RECORD_BYTES)
            .ok_or_else(|| crate::anyhow!("step log record count {n} overflows"))?;
        if need != r.remaining() {
            bail!(
                "{}: log claims {n} records ({need} B) but carries {} B",
                path.display(),
                r.remaining()
            );
        }
        let mut records = Vec::with_capacity(n);
        for _ in 0..n {
            records.push(StepRecord::decode(r.take(STEP_RECORD_BYTES)?));
        }
        Ok(StepLog { records })
    }
}

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub preset: String,
    pub step: u64,
    pub buffers: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn new(preset: &str, step: u64) -> Self {
        Checkpoint { preset: preset.to_string(), step, buffers: BTreeMap::new() }
    }

    pub fn put(&mut self, name: &str, data: &[f32]) {
        self.buffers.insert(name.to_string(), data.to_vec());
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.buffers
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| crate::anyhow!("checkpoint missing buffer {name:?}"))
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        p.extend((self.preset.len() as u32).to_le_bytes());
        p.extend(self.preset.as_bytes());
        p.extend(self.step.to_le_bytes());
        p.extend((self.buffers.len() as u32).to_le_bytes());
        for (name, data) in &self.buffers {
            p.extend((name.len() as u32).to_le_bytes());
            p.extend(name.as_bytes());
            p.extend((data.len() as u64).to_le_bytes());
            for v in data {
                p.extend(v.to_le_bytes());
            }
        }
        p
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let payload = self.payload();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..4] != MAGIC {
            bail!("{}: not a CMZ1 checkpoint", path.display());
        }
        let payload = &bytes[4..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(payload) != stored_crc {
            bail!("{}: CRC mismatch (corrupt checkpoint)", path.display());
        }
        let mut r = Reader { b: payload, i: 0 };
        let plen = r.u32()? as usize;
        let preset = String::from_utf8(r.take(plen)?.to_vec())?;
        let step = r.u64()?;
        let n = r.u32()? as usize;
        let mut buffers = BTreeMap::new();
        for _ in 0..n {
            let nlen = r.u32()? as usize;
            let name = String::from_utf8(r.take(nlen)?.to_vec())?;
            // dlen is untrusted: checked_mul so a crafted u64 errors instead
            // of wrapping `dlen * 4` into a tiny in-bounds read (or a
            // release-mode OOB panic in the old unchecked guard)
            let dlen = r.u64()? as usize;
            let nbytes = dlen
                .checked_mul(4)
                .ok_or_else(|| crate::anyhow!("buffer {name:?} length {dlen} overflows"))?;
            let raw = r.take(nbytes)?;
            let mut data = Vec::with_capacity(dlen);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            buffers.insert(name, data);
        }
        Ok(Checkpoint { preset, step, buffers })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: `self.i + n` with a crafted n wraps in release mode
        // and turns this guard into an out-of-bounds panic — error instead
        let end = match self.i.checked_add(n) {
            Some(e) if e <= self.b.len() => e,
            _ => bail!("truncated checkpoint"),
        };
        let s = &self.b[self.i..end];
        self.i = end;
        Ok(s)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("conmezo_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new("tiny", 1234);
        c.put("params", &[1.0, -2.5, 3.25]);
        c.put("momentum", &[0.0; 100]);
        let p = tmpfile("rt.ckpt");
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(l.preset, "tiny");
        assert_eq!(l.step, 1234);
        assert_eq!(l.get("params").unwrap(), &[1.0, -2.5, 3.25]);
        assert_eq!(l.get("momentum").unwrap().len(), 100);
        assert!(l.get("missing").is_err());
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::new("tiny", 1);
        c.put("params", &[1.0; 64]);
        let p = tmpfile("corrupt.ckpt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let mut c = Checkpoint::new("tiny", 1);
        c.put("params", &[1.0; 64]);
        let p = tmpfile("trunc.ckpt");
        c.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmpfile("magic.ckpt");
        std::fs::write(&p, b"NOPE12345678").unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("not a CMZ1"), "{err}");
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: crc32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn crafted_buffer_length_errors_cleanly() {
        // hand-build a CMZ1 file whose single buffer claims dlen =
        // u64::MAX: `dlen * 4` would wrap to 0x...FFFC — the old unchecked
        // reader either OOB-panicked (release) or overflow-panicked
        // (debug); now it must return an error
        let mut payload = Vec::new();
        payload.extend(4u32.to_le_bytes());
        payload.extend(b"tiny");
        payload.extend(7u64.to_le_bytes()); // step
        payload.extend(1u32.to_le_bytes()); // n_bufs
        payload.extend(1u32.to_le_bytes());
        payload.extend(b"x");
        payload.extend(u64::MAX.to_le_bytes()); // crafted dlen
        let mut bytes = Vec::new();
        bytes.extend(MAGIC);
        bytes.extend(&payload);
        bytes.extend(crc32(&payload).to_le_bytes());
        let p = tmpfile("crafted_dlen.ckpt");
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("overflow") || err.contains("truncated"), "{err}");
    }

    #[test]
    fn crafted_name_length_errors_cleanly() {
        // nlen near usize::MAX exercises the checked_add in Reader::take
        let mut payload = Vec::new();
        payload.extend(4u32.to_le_bytes());
        payload.extend(b"tiny");
        payload.extend(7u64.to_le_bytes());
        payload.extend(1u32.to_le_bytes());
        payload.extend(u32::MAX.to_le_bytes()); // crafted nlen
        let mut bytes = Vec::new();
        bytes.extend(MAGIC);
        bytes.extend(&payload);
        bytes.extend(crc32(&payload).to_le_bytes());
        let p = tmpfile("crafted_nlen.ckpt");
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn step_record_roundtrip() {
        let r = StepRecord { seed: 0xABCD, g: -0.125, theta: 1.35, eta: 1e-3, beta: 0.97 };
        let mut buf = Vec::new();
        r.encode_into(&mut buf);
        assert_eq!(buf.len(), STEP_RECORD_BYTES);
        assert_eq!(StepRecord::decode(&buf), r);
    }

    #[test]
    fn step_log_roundtrip_and_crc() {
        let mut log = StepLog::new();
        for t in 0..50u64 {
            log.records.push(StepRecord {
                seed: t.wrapping_mul(0x9E3779B97F4A7C15),
                g: (t as f64) * 0.01 - 0.2,
                theta: 1.35,
                eta: 1e-3,
                beta: 0.9 + (t as f32) * 1e-3,
            });
        }
        let p = tmpfile("steps.cmzl");
        log.save(&p).unwrap();
        let l = StepLog::load(&p).unwrap();
        assert_eq!(l.records, log.records);
        assert_eq!(l.len(), 50);
        // bit-flip → CRC failure
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();
        let err = StepLog::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn step_log_crafted_count_rejected() {
        // count disagreeing with the byte run must error (even with a
        // valid CRC over the crafted payload)
        let mut payload = Vec::new();
        payload.extend(1000u64.to_le_bytes()); // claims 1000 records, has 0
        let mut bytes = Vec::new();
        bytes.extend(LOG_MAGIC);
        bytes.extend(&payload);
        bytes.extend(crc32(&payload).to_le_bytes());
        let p = tmpfile("crafted_count.cmzl");
        std::fs::write(&p, &bytes).unwrap();
        assert!(StepLog::load(&p).is_err());
    }

    #[test]
    fn params_hash_is_deterministic_and_sensitive() {
        let a = vec![1.0f32, -2.5, 3.25, 0.0];
        let b = vec![1.0f32, -2.5, 3.25, 0.0];
        let mut c = a.clone();
        c[3] = f32::from_bits(1); // one-ulp-from-zero flips the hash
        assert_eq!(params_hash(&a), params_hash(&b));
        assert_ne!(params_hash(&a), params_hash(&c));
        // FNV-1a offset basis for the empty input
        assert_eq!(params_hash(&[]), 0xcbf29ce484222325);
    }
}
