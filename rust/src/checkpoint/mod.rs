//! Binary checkpoint format for flat parameter/optimizer state.
//!
//! Layout (little-endian):
//!   magic "CMZ1" | preset_len u32 | preset bytes | step u64 | n_bufs u32 |
//!   per buf: name_len u32 | name | len u64 | f32 data |
//!   crc32 u32 over everything after the magic
//!
//! CRC is checked on load; truncated or bit-flipped files are rejected —
//! the distributed trainer relies on checkpoint+seed-log replay for worker
//! rejoin, so silent corruption is unacceptable.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"CMZ1";

/// CRC-32 (IEEE) with a lazily built table.
pub fn crc32(data: &[u8]) -> u32 {
    let mut table = [0u32; 256];
    for (i, t) in table.iter_mut().enumerate() {
        let mut c = i as u32;
        for _ in 0..8 {
            c = if c & 1 != 0 { 0xEDB88320 ^ (c >> 1) } else { c >> 1 };
        }
        *t = c;
    }
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub preset: String,
    pub step: u64,
    pub buffers: BTreeMap<String, Vec<f32>>,
}

impl Checkpoint {
    pub fn new(preset: &str, step: u64) -> Self {
        Checkpoint { preset: preset.to_string(), step, buffers: BTreeMap::new() }
    }

    pub fn put(&mut self, name: &str, data: &[f32]) {
        self.buffers.insert(name.to_string(), data.to_vec());
    }

    pub fn get(&self, name: &str) -> Result<&[f32]> {
        self.buffers
            .get(name)
            .map(|v| v.as_slice())
            .ok_or_else(|| crate::anyhow!("checkpoint missing buffer {name:?}"))
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        p.extend((self.preset.len() as u32).to_le_bytes());
        p.extend(self.preset.as_bytes());
        p.extend(self.step.to_le_bytes());
        p.extend((self.buffers.len() as u32).to_le_bytes());
        for (name, data) in &self.buffers {
            p.extend((name.len() as u32).to_le_bytes());
            p.extend(name.as_bytes());
            p.extend((data.len() as u64).to_le_bytes());
            for v in data {
                p.extend(v.to_le_bytes());
            }
        }
        p
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let payload = self.payload();
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?
            .read_to_end(&mut bytes)?;
        if bytes.len() < 8 || &bytes[..4] != MAGIC {
            bail!("{}: not a CMZ1 checkpoint", path.display());
        }
        let payload = &bytes[4..bytes.len() - 4];
        let stored_crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().unwrap());
        if crc32(payload) != stored_crc {
            bail!("{}: CRC mismatch (corrupt checkpoint)", path.display());
        }
        let mut r = Reader { b: payload, i: 0 };
        let plen = r.u32()? as usize;
        let preset = String::from_utf8(r.take(plen)?.to_vec())?;
        let step = r.u64()?;
        let n = r.u32()? as usize;
        let mut buffers = BTreeMap::new();
        for _ in 0..n {
            let nlen = r.u32()? as usize;
            let name = String::from_utf8(r.take(nlen)?.to_vec())?;
            let dlen = r.u64()? as usize;
            let raw = r.take(dlen * 4)?;
            let mut data = Vec::with_capacity(dlen);
            for c in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            buffers.insert(name, data);
        }
        Ok(Checkpoint { preset, step, buffers })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated checkpoint");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("conmezo_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new("tiny", 1234);
        c.put("params", &[1.0, -2.5, 3.25]);
        c.put("momentum", &[0.0; 100]);
        let p = tmpfile("rt.ckpt");
        c.save(&p).unwrap();
        let l = Checkpoint::load(&p).unwrap();
        assert_eq!(l.preset, "tiny");
        assert_eq!(l.step, 1234);
        assert_eq!(l.get("params").unwrap(), &[1.0, -2.5, 3.25]);
        assert_eq!(l.get("momentum").unwrap().len(), 100);
        assert!(l.get("missing").is_err());
    }

    #[test]
    fn corruption_detected() {
        let mut c = Checkpoint::new("tiny", 1);
        c.put("params", &[1.0; 64]);
        let p = tmpfile("corrupt.ckpt");
        c.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
    }

    #[test]
    fn truncation_detected() {
        let mut c = Checkpoint::new("tiny", 1);
        c.put("params", &[1.0; 64]);
        let p = tmpfile("trunc.ckpt");
        c.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
    }

    #[test]
    fn wrong_magic_rejected() {
        let p = tmpfile("magic.ckpt");
        std::fs::write(&p, b"NOPE12345678").unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("not a CMZ1"), "{err}");
    }

    #[test]
    fn crc32_known_vector() {
        // standard test vector: crc32("123456789") = 0xCBF43926
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }
}
