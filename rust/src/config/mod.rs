//! Configuration substrate: a TOML-subset parser + typed access.
//!
//! Supported grammar (sufficient for experiment configs; no serde offline):
//!   * `[section]` and `[section.sub]` headers
//!   * `key = "string" | 123 | 1.5e-3 | true | false | [v, v, ...]`
//!   * `#` comments, blank lines
//!
//! Values are addressed by dotted path (`"train.steps"`). The launcher layers
//! `--set key=value` CLI overrides on top of the file (see cli module).

use std::collections::BTreeMap;

use crate::util::error::{anyhow, bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    /// Parse a scalar literal the way the TOML subset does — also used for
    /// `--set` overrides.
    pub fn parse_scalar(s: &str) -> Result<Value> {
        let t = s.trim();
        if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
            return Ok(Value::Str(t[1..t.len() - 1].to_string()));
        }
        if t == "true" {
            return Ok(Value::Bool(true));
        }
        if t == "false" {
            return Ok(Value::Bool(false));
        }
        if t.starts_with('[') {
            let inner = t
                .strip_prefix('[')
                .and_then(|x| x.strip_suffix(']'))
                .ok_or_else(|| anyhow!("unterminated array: {t}"))?;
            let mut vals = Vec::new();
            if !inner.trim().is_empty() {
                for part in split_top_level(inner) {
                    vals.push(Value::parse_scalar(&part)?);
                }
            }
            return Ok(Value::Array(vals));
        }
        if let Ok(i) = t.parse::<i64>() {
            return Ok(Value::Int(i));
        }
        if let Ok(f) = t.parse::<f64>() {
            return Ok(Value::Float(f));
        }
        // bare word -> string (ergonomic for enum-ish values: optimizer = conmezo)
        if !t.is_empty() && t.chars().all(|c| c.is_alphanumeric() || "-_.".contains(c)) {
            return Ok(Value::Str(t.to_string()));
        }
        bail!("cannot parse value: {t:?}")
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            '[' if !in_str => {
                depth += 1;
                cur.push(c);
            }
            ']' if !in_str => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 && !in_str => {
                parts.push(cur.trim().to_string());
                cur = String::new();
            }
            c => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur.trim().to_string());
    }
    parts
}

/// A flat map of dotted keys to values.
#[derive(Clone, Debug, Default)]
pub struct Config {
    map: BTreeMap<String, Value>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                section = line
                    .strip_prefix('[')
                    .and_then(|l| l.strip_suffix(']'))
                    .ok_or_else(|| anyhow!("line {}: bad section header {raw:?}", lineno + 1))?
                    .trim()
                    .to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value, got {raw:?}", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let val = Value::parse_scalar(v)
                .with_context(|| format!("line {}: key {key}", lineno + 1))?;
            cfg.map.insert(key, val);
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn set(&mut self, key: &str, v: Value) {
        self.map.insert(key.to_string(), v);
    }

    /// Apply a `key=value` override string.
    pub fn set_from_str(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value, got {kv:?}"))?;
        self.map.insert(k.trim().to_string(), Value::parse_scalar(v)?);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    // typed getters with defaults -------------------------------------------

    pub fn str_or(&self, key: &str, default: &str) -> String {
        match self.map.get(key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        match self.map.get(key) {
            Some(Value::Int(i)) => *i,
            Some(Value::Float(f)) => *f as i64,
            _ => default,
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64).max(0) as usize
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.map.get(key) {
            Some(v) => v.as_f64().unwrap_or(default),
            None => default,
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.map.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn f64_list(&self, key: &str) -> Vec<f64> {
        match self.map.get(key) {
            Some(Value::Array(v)) => v.iter().filter_map(|x| x.as_f64()).collect(),
            Some(v) => v.as_f64().into_iter().collect(),
            None => Vec::new(),
        }
    }

    /// Emit back to TOML-subset text (round-trip tested).
    pub fn to_toml(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut last_section = String::new();
        // top-level (section-less) keys must precede any [section] header
        let (top, sectioned): (Vec<_>, Vec<_>) =
            self.map.iter().partition(|(k, _)| !k.contains('.'));
        for (k, v) in top.into_iter().chain(sectioned) {
            let (section, key) = match k.rsplit_once('.') {
                Some((s, key)) => (s.to_string(), key.to_string()),
                None => (String::new(), k.clone()),
            };
            if section != last_section {
                if !section.is_empty() {
                    let _ = writeln!(out, "[{section}]");
                }
                last_section = section;
            }
            let _ = writeln!(out, "{key} = {}", emit_value(v));
        }
        out
    }
}

fn emit_value(v: &Value) -> String {
    match v {
        Value::Str(s) => format!("\"{s}\""),
        Value::Int(i) => i.to_string(),
        Value::Float(f) => {
            if f.fract() == 0.0 && f.abs() < 1e15 {
                format!("{f:.1}")
            } else {
                format!("{f}")
            }
        }
        Value::Bool(b) => b.to_string(),
        Value::Array(xs) => {
            let inner: Vec<String> = xs.iter().map(emit_value).collect();
            format!("[{}]", inner.join(", "))
        }
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "table1"
[train]
steps = 10000
lr = 1e-6
optimizer = conmezo
warmup = true
thetas = [1.35, 1.4]
[model]
preset = "tiny"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "table1");
        assert_eq!(c.i64_or("train.steps", 0), 10000);
        assert!((c.f64_or("train.lr", 0.0) - 1e-6).abs() < 1e-18);
        assert_eq!(c.str_or("train.optimizer", ""), "conmezo");
        assert!(c.bool_or("train.warmup", false));
        assert_eq!(c.f64_list("train.thetas"), vec![1.35, 1.4]);
        assert_eq!(c.str_or("model.preset", ""), "tiny");
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("x", 7), 7);
        assert_eq!(c.str_or("y", "z"), "z");
    }

    #[test]
    fn overrides() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.set_from_str("train.steps=99").unwrap();
        c.set_from_str("model.preset=\"small\"").unwrap();
        assert_eq!(c.i64_or("train.steps", 0), 99);
        assert_eq!(c.str_or("model.preset", ""), "small");
    }

    #[test]
    fn roundtrip_through_toml() {
        let c = Config::parse(SAMPLE).unwrap();
        let c2 = Config::parse(&c.to_toml()).unwrap();
        for k in c.keys() {
            assert_eq!(c.get(k), c2.get(k), "key {k}");
        }
    }

    #[test]
    fn comments_inside_strings_preserved() {
        let c = Config::parse("k = \"a#b\" # real comment").unwrap();
        assert_eq!(c.str_or("k", ""), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("[unclosed").is_err());
        assert!(Config::parse("k = [1, ").is_err());
    }

    #[test]
    fn nested_arrays() {
        let c = Config::parse("k = [[1, 2], [3]]").unwrap();
        match c.get("k") {
            Some(Value::Array(outer)) => {
                assert_eq!(outer.len(), 2);
                assert_eq!(outer[0], Value::Array(vec![Value::Int(1), Value::Int(2)]));
            }
            other => panic!("expected array, got {other:?}"),
        }
    }
}
