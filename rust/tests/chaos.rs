//! Deterministic chaos harness (ISSUE 10 tentpole): leader + workers over
//! in-memory channel transports, driven through seeded fault storms from
//! [`ChaosPlan`]. The invariant under test is the durability contract:
//!
//! * **Non-lethal storms** (delays only, inside every timeout window) must
//!   be fully absorbed — the run completes and every replica ends
//!   bit-identical to the fault-free baseline.
//! * **Lethal storms** (kills, corrupt/truncated frames, reordering) may
//!   end the run, but only in a *classified* way: the leader either
//!   finishes with its survivors bit-identical to a replay of its own WAL,
//!   or aborts with an error the taxonomy can name. Dead workers must hold
//!   a classified error too. Nothing may hang and nothing may silently
//!   diverge.
//!
//! Every storm is replayable from its seed alone — a failure here is a
//! deterministic repro, not flake.

use std::thread;
use std::time::Duration;

use conmezo::checkpoint::load_wal;
use conmezo::coordinator::{
    run_worker_with, DistHypers, Leader, LeaderConfig, WorkerOpts, ZoWorker,
};
use conmezo::net::{channel_pair, ChaosPlan, FaultTransport, Transport, TransportErrorKind};
use conmezo::objective::Objective;
use conmezo::optimizer::BetaSchedule;
use conmezo::util::error::Result;

const D: usize = 32;
const N: u32 = 3;
const STEPS: u64 = 12;
const HYP: DistHypers = DistHypers { theta: 1.2, eta: 1e-3, lam: 1e-2 };

fn x0() -> Vec<f32> {
    (0..D).map(|i| ((i * 31 + 7) as f32 * 0.1).cos()).collect()
}

/// Per-shard quadratic with a shard-dependent linear term, so losing a
/// replica visibly changes the averaged gradient — silent divergence after
/// a drop cannot hide behind symmetric objectives.
struct ShardQuad {
    d: usize,
    shift: f64,
    evals: u64,
}

impl Objective for ShardQuad {
    fn dim(&self) -> usize {
        self.d
    }

    fn d_raw(&self) -> usize {
        self.d
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        self.evals += 1;
        Ok(x.iter().map(|&xi| {
            let xi = xi as f64;
            0.5 * xi * xi + self.shift * xi
        }).sum())
    }

    fn two_point(&mut self, x: &[f32], z: &[f32], lam: f32) -> Result<(f64, f64)> {
        self.evals += 2;
        let lam = lam as f64;
        let (mut lp, mut lm) = (0f64, 0f64);
        for i in 0..self.d {
            let (xi, zi) = (x[i] as f64, z[i] as f64);
            let p = xi + lam * zi;
            let m = xi - lam * zi;
            lp += 0.5 * p * p + self.shift * p;
            lm += 0.5 * m * m + self.shift * m;
        }
        Ok((lp, lm))
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

fn shard(id: u32) -> Box<dyn Objective> {
    Box::new(ShardQuad { d: D, shift: (id as f64 + 1.0) * 0.05, evals: 0 })
}

/// Outcome of one storm: the leader's result plus each worker's terminal
/// state `(result, params, step)`.
struct Storm {
    leader: std::result::Result<(), String>,
    workers: Vec<(std::result::Result<(), String>, Vec<f32>, u64)>,
}

/// Drive one run to completion. `storm` seeds the fault scripts (`None` =
/// clean transports, the fault-free baseline); `wal` optionally persists
/// the leader's step log so survivors can be checked against a replay.
fn run_storm(storm: Option<(u64, bool)>, wal: Option<std::path::PathBuf>) -> Storm {
    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..N {
        let (wside, lside) = channel_pair();
        conns.push(Box::new(lside));
        let faults = storm
            .map(|(seed, lethal)| ChaosPlan::new(seed).faults_for(id, 2 * STEPS, lethal))
            .unwrap_or_default();
        handles.push(thread::spawn(move || {
            let mut conn: Box<dyn Transport> = if faults.is_empty() {
                Box::new(wside)
            } else {
                Box::new(FaultTransport::new(Box::new(wside), faults))
            };
            let mut w = ZoWorker::new(id, x0(), shard(id));
            let res = run_worker_with(conn.as_mut(), &mut w, &WorkerOpts::default())
                .map_err(|e| e.to_string());
            (res, w.x, w.t)
        }));
    }

    let mut cfg = LeaderConfig::new(N, 42, STEPS, HYP, BetaSchedule::Constant(0.9));
    // windows far wider than any injected delay (<= 20 ms): a non-lethal
    // storm must never cost a straggler skip, which would change g
    cfg.proj_timeout = Some(Duration::from_secs(5));
    cfg.hash_check_every = 4;
    cfg.step_log = wal;
    let leader_res = Leader::new(cfg).run(conns).map(|_| ()).map_err(|e| e.to_string());
    let workers: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    Storm { leader: leader_res, workers }
}

/// "Classified" = the transport taxonomy names it, or it is one of the
/// protocol-level aborts the engine raises deliberately. A bland unnamed
/// error is exactly the failure mode this suite exists to catch.
fn classified(msg: &str) -> bool {
    TransportErrorKind::classify_str(msg).is_some()
        || msg.contains("divergence tripwire")
        || msg.contains("workers lost")
        || msg.contains("protocol desync")
        || msg.contains("without matching Step")
        || msg.contains("protocol violation")
        || msg.contains("expected ")
}

fn fault_free_baseline() -> Vec<Vec<f32>> {
    let storm = run_storm(None, None);
    assert!(storm.leader.is_ok(), "baseline run failed: {:?}", storm.leader);
    storm.workers.into_iter().map(|(res, x, t)| {
        assert!(res.is_ok(), "baseline worker failed: {res:?}");
        assert_eq!(t, STEPS);
        x
    }).collect()
}

#[test]
fn nonlethal_storms_converge_bit_identical() {
    let baseline = fault_free_baseline();
    for seed in 1..=8u64 {
        let storm = run_storm(Some((seed, false)), None);
        assert!(
            storm.leader.is_ok(),
            "non-lethal storm (seed {seed}) killed the run: {:?}",
            storm.leader
        );
        for (id, (res, x, t)) in storm.workers.iter().enumerate() {
            assert!(res.is_ok(), "non-lethal storm (seed {seed}) killed worker {id}: {res:?}");
            assert_eq!(*t, STEPS, "worker {id} stopped early under seed {seed}");
            assert_eq!(
                x, &baseline[id],
                "worker {id} diverged from the fault-free baseline under seed {seed}"
            );
        }
    }
}

#[test]
fn lethal_storms_abort_classified_or_converge() {
    let baseline = fault_free_baseline();
    let dir = std::env::temp_dir().join(format!("conmezo_chaos_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut aborted = 0u32;
    let mut survived_with_losses = 0u32;
    for seed in 1..=12u64 {
        let wal_path = dir.join(format!("storm_{seed}.cmzw"));
        let _ = std::fs::remove_file(&wal_path);
        let storm = run_storm(Some((seed, true)), Some(wal_path.clone()));

        // every dead worker must know WHY it died
        for (id, (res, _, _)) in storm.workers.iter().enumerate() {
            if let Err(msg) = res {
                assert!(
                    classified(msg),
                    "worker {id} died unclassified under seed {seed}: {msg}"
                );
            }
        }

        match &storm.leader {
            Err(msg) => {
                assert!(classified(msg), "leader aborted unclassified under seed {seed}: {msg}");
                aborted += 1;
            }
            Ok(()) => {
                let finishers: Vec<_> =
                    storm.workers.iter().filter(|(res, _, t)| res.is_ok() && *t == STEPS).collect();
                assert!(!finishers.is_empty(), "run 'succeeded' with zero finishers (seed {seed})");
                let lost = storm.workers.len() - finishers.len();
                if lost == 0 {
                    // the storm was absorbed entirely: full bit-identity
                    for (id, (_, x, _)) in storm.workers.iter().enumerate() {
                        assert_eq!(x, &baseline[id], "silent divergence under seed {seed}");
                    }
                } else {
                    // survivors must agree with a replay of the leader's own
                    // WAL — the no-silent-divergence half of the contract
                    survived_with_losses += 1;
                    let rec = load_wal(&wal_path).unwrap();
                    assert_eq!(rec.log.records.len() as u64, STEPS);
                    let mut replica = ZoWorker::new(0, x0(), shard(0));
                    replica.replay(0, &rec.log.records).unwrap();
                    for (id, (res, x, t)) in storm.workers.iter().enumerate() {
                        if res.is_ok() && *t == STEPS {
                            assert_eq!(
                                x, &replica.x,
                                "survivor {id} diverged from the WAL replay under seed {seed}"
                            );
                        }
                    }
                }
            }
        }
        let _ = std::fs::remove_file(&wal_path);
    }
    // the seeded plans must actually exercise both terminal branches;
    // if this trips, widen the seed range rather than weakening the test
    assert!(
        aborted + survived_with_losses > 0,
        "no lethal storm did anything lethal — the chaos plan is toothless"
    );
}

#[test]
fn chaos_runs_never_hang() {
    // belt-and-braces liveness pin: a full lethal sweep bounded by a hard
    // wall-clock budget (each storm is 12 steps of a 32-d quadratic; even
    // with max delays this is comfortably under the bound)
    let start = std::time::Instant::now();
    for seed in 100..=105u64 {
        let _ = run_storm(Some((seed, true)), None);
    }
    assert!(
        start.elapsed() < Duration::from_secs(120),
        "lethal sweep exceeded its liveness budget"
    );
}
