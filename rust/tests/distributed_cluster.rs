//! Integration tests for the fault-tolerant cluster engine (ISSUE 6):
//! a real multi-worker TCP leader/worker run pinned bit-identical to
//! `LocalCluster` (including the corrected wire accounting), plus
//! fault-injection coverage over in-memory transports — worker death
//! mid-step with live-count renormalization, straggler timeouts that
//! skip-but-keep a slow replica, seed-replay rejoin after a kill, and the
//! all-workers-lost abort. The injection harness is `FaultTransport`
//! (scripted per-call delays/kills), so every failure mode is exercised
//! deterministically without flaky socket games.

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use conmezo::checkpoint::StepLog;
use conmezo::coordinator::{
    run_leader, run_worker, run_worker_with, DistHypers, Leader, LeaderConfig, LocalCluster,
    WorkerOpts, ZoWorker,
};
use conmezo::net::{
    channel_pair, ChannelTransport, Fault, FaultTransport, TcpTransport, Transport,
    TransportErrorKind,
};
use conmezo::objective::Objective;
use conmezo::optimizer::BetaSchedule;
use conmezo::util::error::Result;

const D: usize = 48;
const HYP: DistHypers = DistHypers { theta: 1.2, eta: 1e-3, lam: 1e-2 };

fn beta() -> BetaSchedule {
    BetaSchedule::Constant(0.9)
}

fn x0() -> Vec<f32> {
    (0..D).map(|i| ((i * 37 + 11) as f32 * 0.1).sin()).collect()
}

/// Per-shard objective: 0.5‖x‖² + shift·Σx. The linear term makes each
/// worker's projected gradient shard-dependent, so dropping one replica
/// from the step average visibly changes g — renormalization by the live
/// count is observable, unlike with identical quadratics.
struct ShardQuad {
    d: usize,
    shift: f64,
    evals: u64,
}

impl Objective for ShardQuad {
    fn dim(&self) -> usize {
        self.d
    }

    fn d_raw(&self) -> usize {
        self.d
    }

    fn loss(&mut self, x: &[f32]) -> Result<f64> {
        self.evals += 1;
        let mut l = 0f64;
        for &xi in x {
            let xi = xi as f64;
            l += 0.5 * xi * xi + self.shift * xi;
        }
        Ok(l)
    }

    fn two_point(&mut self, x: &[f32], z: &[f32], lam: f32) -> Result<(f64, f64)> {
        self.evals += 2;
        let lam = lam as f64;
        let (mut lp, mut lm) = (0f64, 0f64);
        for i in 0..self.d {
            let (xi, zi) = (x[i] as f64, z[i] as f64);
            let p = xi + lam * zi;
            let m = xi - lam * zi;
            lp += 0.5 * p * p + self.shift * p;
            lm += 0.5 * m * m + self.shift * m;
        }
        Ok((lp, lm))
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

fn shard(id: u32) -> Box<dyn Objective> {
    Box::new(ShardQuad { d: D, shift: (id as f64 + 1.0) * 0.05, evals: 0 })
}

fn temp_path(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("conmezo_cluster_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{}", std::process::id(), name))
}

/// Fast-forward a fresh replica through the leader's step log — the ground
/// truth every live worker must agree with bitwise.
fn replay_log(records: &[conmezo::checkpoint::StepRecord]) -> ZoWorker {
    let mut w = ZoWorker::new(0, x0(), shard(0));
    w.replay(0, records).unwrap();
    w
}

#[test]
fn tcp_cluster_matches_local_cluster_bitwise() {
    // satellite (e): N=3 over real localhost TCP vs the in-process
    // LocalCluster — replicas bit-identical AND the wire accounting equal
    // (the old leader's hardcoded 29 B per Proj vs the actual 33 B frame)
    let n = 3u32;
    let steps = 30u64;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let mut handles = Vec::new();
    for id in 0..n {
        let addr = addr.clone();
        handles.push(thread::spawn(move || {
            let mut conn = TcpTransport::connect_retry(
                &addr,
                id,
                40,
                Duration::from_millis(5),
                Duration::from_millis(50),
            )
            .unwrap();
            let mut w = ZoWorker::new(id, x0(), shard(id));
            run_worker(&mut conn, &mut w).unwrap();
            (w.x, w.m, w.t)
        }));
    }
    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    for _ in 0..n {
        let (stream, _) = listener.accept().unwrap();
        conns.push(Box::new(TcpTransport::new(stream).unwrap()));
    }
    let summary = run_leader(conns, 42, steps, HYP, &beta(), 0).unwrap();
    let states: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    let workers = (0..n).map(|id| ZoWorker::new(id, x0(), shard(id))).collect();
    let mut local = LocalCluster::new(workers, 42);
    let local_summary = local.run(steps, HYP, &beta(), 0).unwrap();

    assert_eq!(
        summary.wire_bytes, local_summary.wire_bytes,
        "TCP leader and LocalCluster disagree on wire bytes"
    );
    for (id, (x, m, t)) in states.iter().enumerate() {
        assert_eq!(*t, steps, "worker {id} stopped early");
        assert_eq!(x, &local.workers[id].x, "worker {id} params diverged over TCP");
        assert_eq!(m, &local.workers[id].m, "worker {id} momentum diverged over TCP");
    }
    assert_eq!(summary.workers_lost, 0);
    assert_eq!(summary.straggler_events, 0);
    assert_eq!(summary.rejoins, 0);
}

#[test]
fn worker_death_renormalizes_over_survivors_and_log_replays() {
    // worker 2 crashes receiving Step{die_at}; the leader must drop it,
    // average g over the two survivors (NOT the nominal 3 — pinned bitwise
    // below), finish the run, and persist a replayable step log
    let n = 3u32;
    let steps = 30u64;
    let die_at = 7u64;
    let log_path = temp_path("death.cmzw");
    let _ = std::fs::remove_file(&log_path);

    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..n {
        let (wside, lside) = channel_pair();
        conns.push(Box::new(lside));
        handles.push(thread::spawn(move || {
            let mut wside = wside;
            let mut w = ZoWorker::new(id, x0(), shard(id));
            let opts = WorkerOpts {
                die_at_step: if id == 2 { Some(die_at) } else { None },
                ..Default::default()
            };
            let res = run_worker_with(&mut wside, &mut w, &opts).map_err(|e| e.to_string());
            (res, w.x, w.m, w.t)
        }));
    }

    let mut cfg = LeaderConfig::new(n, 42, steps, HYP, beta());
    cfg.proj_timeout = Some(Duration::from_secs(5));
    cfg.step_log = Some(log_path.clone());
    let summary = Leader::new(cfg).run(conns).unwrap();
    let states: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    assert_eq!(summary.workers_lost, 1);
    assert_eq!(summary.rejoins, 0);
    let (res2, _, _, t2) = &states[2];
    let err = res2.as_ref().unwrap_err();
    assert_eq!(
        TransportErrorKind::classify_str(err),
        Some(TransportErrorKind::FaultInjected),
        "{err}"
    );
    assert_eq!(*t2, die_at, "crashed worker applied steps past its death");
    for id in 0..2 {
        let (res, x, m, t) = &states[id];
        assert!(res.is_ok(), "survivor {id} errored: {res:?}");
        assert_eq!(*t, steps);
        assert_eq!(x, &states[0].1, "survivors diverged");
        assert_eq!(m, &states[0].2, "survivor momentum diverged");
    }

    // the persisted log replays a fresh replica to the survivors' exact state
    let log = StepLog::load(&log_path).unwrap();
    assert_eq!(log.records.len() as u64, steps);
    let replica = replay_log(&log.records);
    assert_eq!(replica.x, states[0].1, "step-log replay diverged from survivors");
    assert_eq!(replica.m, states[0].2);

    // pin the renormalization bitwise: at the death step g must be the mean
    // over the TWO live projections, computed exactly as the leader does
    let r = &log.records[die_at as usize];
    let mut g_sum = 0f64;
    for id in 0..2u32 {
        let mut w = ZoWorker::new(id, x0(), shard(id));
        w.replay(0, &log.records[..die_at as usize]).unwrap();
        let (lp, lm) = w.compute_proj(die_at, r.seed, r.theta, HYP.lam).unwrap();
        g_sum += (lp - lm) / (2.0 * HYP.lam as f64);
    }
    let g_expected = g_sum / 2.0;
    assert_eq!(
        r.g.to_bits(),
        g_expected.to_bits(),
        "death-step g was not renormalized over the live count: {} vs {}",
        r.g,
        g_expected
    );
    let _ = std::fs::remove_file(&log_path);
}

#[test]
fn straggler_is_skipped_but_stays_bit_identical() {
    // worker 1's Proj for one step is delayed past the leader's window:
    // the leader must skip it (strike, renormalize over the others), keep
    // the replica in the cluster, and — because Apply still reaches it —
    // end the run with all three replicas bit-identical
    let n = 3u32;
    let steps = 20u64;
    let lag_step = 6u64;
    let log_path = temp_path("straggler.cmzw");
    let _ = std::fs::remove_file(&log_path);

    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..n {
        let (wside, lside) = channel_pair();
        conns.push(Box::new(lside));
        handles.push(thread::spawn(move || {
            let mut w = ZoWorker::new(id, x0(), shard(id));
            // worker 1's send sequence: 0=Hello, 1=Ready, 2+t=Proj{t};
            // stall its Proj{lag_step} well past the leader's 80 ms window
            let mut conn: Box<dyn Transport> = if id == 1 {
                Box::new(FaultTransport::new(
                    Box::new(wside),
                    vec![Fault::DelaySend { at: 2 + lag_step, by: Duration::from_millis(400) }],
                ))
            } else {
                Box::new(wside)
            };
            run_worker(conn.as_mut(), &mut w).unwrap();
            (w.x, w.m, w.t)
        }));
    }

    let mut cfg = LeaderConfig::new(n, 42, steps, HYP, beta());
    cfg.proj_timeout = Some(Duration::from_millis(80));
    // the stall spans a handful of 80 ms windows; plenty of headroom so the
    // straggler is skipped, never dropped
    cfg.max_strikes = 50;
    cfg.step_log = Some(log_path.clone());
    let summary = Leader::new(cfg).run(conns).unwrap();
    let states: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    assert!(summary.straggler_events >= 1, "the delayed Proj never timed out");
    assert_eq!(summary.workers_lost, 0, "straggler must be skipped, not dropped");
    for (id, (x, m, t)) in states.iter().enumerate() {
        assert_eq!(*t, steps, "worker {id} stopped early");
        assert_eq!(x, &states[0].0, "worker {id} diverged after straggling");
        assert_eq!(m, &states[0].1);
    }
    // and the logged trajectory matches what every replica applied
    let log = StepLog::load(&log_path).unwrap();
    let replica = replay_log(&log.records);
    assert_eq!(replica.x, states[0].0);
    let _ = std::fs::remove_file(&log_path);
}

#[test]
fn killed_worker_rejoins_via_seed_replay_bit_identical() {
    // the acceptance scenario in-process: worker 2 is killed at step
    // `die_at`, reconnects later with its retained state, catches up through
    // chunked Replay records with zero function evals, survives the
    // post-rejoin hash tripwire, and finishes bit-identical to the replicas
    // that never died
    let n = 3u32;
    let steps = 60u64;
    let die_at = 5u64;
    let (jtx, jrx) = mpsc::channel::<ChannelTransport>();

    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..n {
        let (wside, lside) = channel_pair();
        conns.push(Box::new(lside));
        let jtx = jtx.clone();
        handles.push(thread::spawn(move || {
            let mut w = ZoWorker::new(id, x0(), shard(id));
            if id == 2 {
                let mut first = wside;
                let opts = WorkerOpts { die_at_step: Some(die_at), ..Default::default() };
                let err = run_worker_with(&mut first, &mut w, &opts).unwrap_err();
                assert_eq!(
                    TransportErrorKind::classify(&err),
                    Some(TransportErrorKind::FaultInjected),
                    "{err}"
                );
                drop(first); // the leader sees a dead connection
                // reconnect with the same replica: only die_at..T replays
                let (mut wside2, lside2) = channel_pair();
                jtx.send(lside2).unwrap();
                run_worker_with(&mut wside2, &mut w, &WorkerOpts::default()).unwrap();
            } else {
                let mut wside = wside;
                run_worker(&mut wside, &mut w).unwrap();
            }
            (w.x, w.m, w.t)
        }));
    }
    drop(jtx);

    let mut cfg = LeaderConfig::new(n, 42, steps, HYP, beta());
    cfg.proj_timeout = Some(Duration::from_secs(5));
    let summary = Leader::new(cfg)
        .run_with_joiner(conns, |_t| {
            jrx.try_iter().map(|c| Box::new(c) as Box<dyn Transport>).collect()
        })
        .unwrap();
    let states: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    assert_eq!(summary.workers_lost, 1);
    assert_eq!(summary.rejoins, 1, "the leader never saw the rejoin");
    for (id, (x, m, t)) in states.iter().enumerate() {
        assert_eq!(*t, steps, "worker {id} (rejoined: {}) stopped early", id == 2);
        assert_eq!(x, &states[0].0, "worker {id} diverged — rejoin replay is broken");
        assert_eq!(m, &states[0].1, "worker {id} momentum diverged after rejoin");
    }
}

#[test]
fn leader_bails_when_all_workers_lost() {
    let n = 2u32;
    let steps = 30u64;
    let die_at = 3u64;

    let mut conns: Vec<Box<dyn Transport>> = Vec::new();
    let mut handles = Vec::new();
    for id in 0..n {
        let (wside, lside) = channel_pair();
        conns.push(Box::new(lside));
        handles.push(thread::spawn(move || {
            let mut wside = wside;
            let mut w = ZoWorker::new(id, x0(), shard(id));
            let opts = WorkerOpts { die_at_step: Some(die_at), ..Default::default() };
            run_worker_with(&mut wside, &mut w, &opts).map_err(|e| e.to_string())
        }));
    }

    let mut cfg = LeaderConfig::new(n, 42, steps, HYP, beta());
    cfg.proj_timeout = Some(Duration::from_secs(5));
    let err = Leader::new(cfg).run(conns).unwrap_err().to_string();
    assert!(err.contains("all 2 workers lost"), "{err}");
    for h in handles {
        let res = h.join().unwrap();
        let err = res.unwrap_err();
        assert_eq!(
            TransportErrorKind::classify_str(&err),
            Some(TransportErrorKind::FaultInjected),
            "{err}"
        );
    }
}
