//! Integration tests over the pluggable runtime.
//!
//! The default suite runs against the NativeBackend — always available, no
//! artifacts needed — and asserts (a) golden-value parity with the jax
//! reference via checked-in fixtures (`fixtures/native_parity.json`,
//! regenerate with `python -m compile.gen_fixtures`), and (b) exact
//! fused-vs-composed step equivalence, which the native backend guarantees
//! bitwise because both paths share the same vecmath kernels.
//!
//! The first-order programs (native reverse-mode autograd) are pinned the
//! same way by `fixtures/fo_parity.json`: loss, gradient norm + sampled
//! coordinates, the Fig. 6 `grad_cos2` probe and a two-step AdamW
//! trajectory, all against `jax.value_and_grad` golden values.
//!
//! PJRT-only assertions (AOT artifacts, cross-backend parity) live in the
//! `pjrt_parity` module behind `#[cfg(feature = "pjrt")]` and skip
//! gracefully when `artifacts/` is absent.

use conmezo::coordinator::{FusedConMeZo, FusedMezo};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::{BatchSource, ModelObjective, NativeQuadratic, Objective};
use conmezo::runtime::{lit_f32, lit_vec_f32, Arg, Runtime, Session};
use conmezo::util::json::Json;
use conmezo::vecmath;

fn runtime() -> Runtime {
    Runtime::native()
}

// ---------------------------------------------------------------------------
// golden-value parity with the jax reference
// ---------------------------------------------------------------------------

const FIXTURE: &str = include_str!("fixtures/native_parity.json");

fn fixture_i32s(j: &Json, key: &str) -> Vec<i32> {
    j.expect(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_i64().unwrap() as i32)
        .collect()
}

fn fixture_f32s(j: &Json, key: &str) -> Vec<f32> {
    j.expect(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn native_loss_matches_reference_fixture() {
    let fx = Json::parse(FIXTURE).unwrap();
    let exp = fx.expect("expected").unwrap();
    let tol = fx.expect("tolerance").unwrap().as_f64().unwrap();
    let preset = fx.expect("preset").unwrap().as_str().unwrap().to_string();
    let (b, s) = (
        fx.expect("batch").unwrap().as_usize().unwrap(),
        fx.expect("seq").unwrap().as_usize().unwrap(),
    );
    let ids = fixture_i32s(&fx, "input_ids");
    let tgt = fixture_i32s(&fx, "targets");
    let mask = fixture_f32s(&fx, "mask");
    let init_seed = fx.expect("init_seed").unwrap().as_i64().unwrap() as i32;
    let z_seed = fx.expect("z_seed").unwrap().as_i64().unwrap() as i32;
    let lam = fx.expect("lam").unwrap().as_f64().unwrap() as f32;

    let rt = runtime();
    let init = rt.load_kind(&preset, "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(init_seed)]).unwrap()[0]).unwrap();

    // the init PRNG mirror is pinned by sum/sumsq checksums
    let psum: f64 = params.iter().map(|&v| v as f64).sum();
    let psumsq: f64 = params.iter().map(|&v| (v as f64) * (v as f64)).sum();
    let want_sum = exp.expect("params_sum").unwrap().as_f64().unwrap();
    let want_sumsq = exp.expect("params_sumsq").unwrap().as_f64().unwrap();
    assert!((psum - want_sum).abs() < 0.05, "params sum {psum} vs {want_sum}");
    assert!(
        (psumsq - want_sumsq).abs() / want_sumsq < 1e-3,
        "params sumsq {psumsq} vs {want_sumsq}"
    );

    let sample_u = rt.load_kind(&preset, "sample_u").unwrap();
    let z = lit_vec_f32(&sample_u.call(&[Arg::I32(z_seed)]).unwrap()[0]).unwrap();
    let usum: f64 = z.iter().map(|&v| v as f64).sum();
    let usumsq: f64 = z.iter().map(|&v| (v as f64) * (v as f64)).sum();
    assert!((usum - exp.expect("u_sum").unwrap().as_f64().unwrap()).abs() < 0.5, "{usum}");
    let want_usumsq = exp.expect("u_sumsq").unwrap().as_f64().unwrap();
    assert!((usumsq - want_usumsq).abs() / want_usumsq < 1e-3, "{usumsq}");

    let dims = vec![b, s];
    let loss_prog = rt.load_kind(&preset, "loss").unwrap();
    let outs = loss_prog
        .call(&[
            Arg::VecF32(&params),
            Arg::TensorI32(&ids, dims.clone()),
            Arg::TensorI32(&tgt, dims.clone()),
            Arg::TensorF32(&mask, dims.clone()),
        ])
        .unwrap();
    let loss = lit_f32(&outs[0]).unwrap() as f64;
    let want = exp.expect("loss").unwrap().as_f64().unwrap();
    assert!((loss - want).abs() < tol * want.abs().max(1.0), "loss {loss} vs jax {want}");

    // two_point against the reference perturbed losses
    let tp = rt.load_kind(&preset, "two_point").unwrap();
    let outs = tp
        .call(&[
            Arg::VecF32(&params),
            Arg::VecF32(&z),
            Arg::F32(lam),
            Arg::TensorI32(&ids, dims.clone()),
            Arg::TensorI32(&tgt, dims.clone()),
            Arg::TensorF32(&mask, dims.clone()),
        ])
        .unwrap();
    let (lp, lm) = (lit_f32(&outs[0]).unwrap() as f64, lit_f32(&outs[1]).unwrap() as f64);
    let want_lp = exp.expect("loss_plus").unwrap().as_f64().unwrap();
    let want_lm = exp.expect("loss_minus").unwrap().as_f64().unwrap();
    assert!((lp - want_lp).abs() < tol * want_lp.abs().max(1.0), "lp {lp} vs {want_lp}");
    assert!((lm - want_lm).abs() < tol * want_lm.abs().max(1.0), "lm {lm} vs {want_lm}");
    // ... and the projected gradient they imply must agree to ~1e-2 relative
    // (it is a difference of nearly equal numbers)
    let g = (lp - lm) / (2.0 * lam as f64);
    let want_g = (want_lp - want_lm) / (2.0 * lam as f64);
    assert!((g - want_g).abs() < 2e-2 * want_g.abs().max(0.1), "g {g} vs {want_g}");

    // eval_logits row 0
    let pos = fixture_i32s(&fx, "eval_pos");
    let ev = rt.load_kind(&preset, "eval_logits").unwrap();
    let outs = ev
        .call(&[
            Arg::VecF32(&params),
            Arg::TensorI32(&ids, dims),
            Arg::TensorI32(&pos, vec![b]),
        ])
        .unwrap();
    let logits = lit_vec_f32(&outs[0]).unwrap();
    let want_row = fixture_f32s(exp, "eval_logits_row0");
    assert_eq!(logits.len() / b, want_row.len());
    for (i, (&got, &want)) in logits[..want_row.len()].iter().zip(&want_row).enumerate() {
        assert!(
            (got - want).abs() < tol as f32 * want.abs().max(1.0),
            "logit {i}: {got} vs {want}"
        );
    }
}

// ---------------------------------------------------------------------------
// first-order parity: the native reverse pass against jax.value_and_grad
// ---------------------------------------------------------------------------

const FO_FIXTURE: &str = include_str!("fixtures/fo_parity.json");

#[test]
fn native_first_order_programs_match_jax_fixture() {
    let fx = Json::parse(FO_FIXTURE).unwrap();
    let exp = fx.expect("expected").unwrap();
    let preset = fx.expect("preset").unwrap().as_str().unwrap().to_string();
    let (b, s) = (
        fx.expect("batch").unwrap().as_usize().unwrap(),
        fx.expect("seq").unwrap().as_usize().unwrap(),
    );
    let ids = fixture_i32s(&fx, "input_ids");
    let tgt = fixture_i32s(&fx, "targets");
    let mask = fixture_f32s(&fx, "mask");
    let init_seed = fx.expect("init_seed").unwrap().as_i64().unwrap() as i32;
    let m_seed = fx.expect("m_seed").unwrap().as_i64().unwrap() as i32;
    let sgd_eta = fx.expect("sgd_eta").unwrap().as_f64().unwrap() as f32;
    let adamw_eta = fx.expect("adamw_eta").unwrap().as_f64().unwrap() as f32;
    let stride = fx.expect("grad_sample_stride").unwrap().as_usize().unwrap();

    let rt = runtime();
    let meta = rt.preset(&preset).unwrap().clone();
    let init = rt.load_kind(&preset, "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(init_seed)]).unwrap()[0]).unwrap();
    let sample_u = rt.load_kind(&preset, "sample_u").unwrap();
    let m = lit_vec_f32(&sample_u.call(&[Arg::I32(m_seed)]).unwrap()[0]).unwrap();
    let dims = vec![b, s];
    let batch3 = || {
        (
            Arg::TensorI32(&ids, dims.clone()),
            Arg::TensorI32(&tgt, dims.clone()),
            Arg::TensorF32(&mask, dims.clone()),
        )
    };

    // gradient via fo_sgd_step at eta = -1 (params' = params + grad)
    let sgd = rt.load_kind(&preset, "fo_sgd_step").unwrap();
    let (i, t, k) = batch3();
    let outs = sgd.call(&[Arg::VecF32(&params), Arg::F32(-1.0), i, t, k]).unwrap();
    let shifted = lit_vec_f32(&outs[0]).unwrap();
    let loss = lit_f32(&outs[1]).unwrap() as f64;
    let grad: Vec<f32> = shifted.iter().zip(&params).map(|(a, b)| a - b).collect();

    let want_loss = exp.expect("loss").unwrap().as_f64().unwrap();
    assert!((loss - want_loss).abs() < 1e-3 * want_loss.abs().max(1.0), "loss {loss} vs jax {want_loss}");

    // pads carry no gradient
    assert!(grad[meta.d_raw..].iter().all(|&g| g == 0.0));

    // gradient norm within 1e-3 relative of the jax value
    let grad_l2 = vecmath::nrm2(&grad);
    let want_l2 = exp.expect("grad_l2").unwrap().as_f64().unwrap();
    assert!(
        (grad_l2 - want_l2).abs() / want_l2 < 1e-3,
        "grad l2 {grad_l2} vs jax {want_l2}"
    );

    // sampled coordinates (stride over d_raw), rel 1e-2 with a 1e-3 floor —
    // the numpy mirror of this exact math deviates from jax by < 1e-5 rel,
    // but near-cancelling coordinates (|g| ~ 1e-5) need the absolute floor
    // so cross-compiler f32 contraction differences cannot flake the test
    let samples = fixture_f32s(exp, "grad_samples");
    for (si, want) in samples.iter().enumerate() {
        let got = grad[si * stride] as f64;
        let rel = (got - *want as f64).abs() / (*want as f64).abs().max(1e-3);
        assert!(rel < 1e-2, "grad[{}]: native {got} vs jax {want} (rel {rel:.2e})", si * stride);
    }

    // the Fig. 6 probe: cos^2(m, grad f) within 1e-3 relative of jax
    let probe = rt.load_kind(&preset, "grad_cos2").unwrap();
    let (i, t, k) = batch3();
    let outs = probe.call(&[Arg::VecF32(&params), Arg::VecF32(&m), i, t, k]).unwrap();
    let cos2 = lit_f32(&outs[0]).unwrap() as f64;
    let probe_loss = lit_f32(&outs[1]).unwrap() as f64;
    let want_cos2 = exp.expect("grad_cos2").unwrap().as_f64().unwrap();
    assert!(
        (cos2 - want_cos2).abs() / want_cos2.abs().max(1e-9) < 1e-3,
        "grad_cos2 {cos2} vs jax {want_cos2}"
    );
    assert!((probe_loss - want_loss).abs() < 1e-3 * want_loss.abs().max(1.0));

    // sgd displacement: ||x' - x|| = eta * ||grad||
    let (i, t, k) = batch3();
    let outs = sgd.call(&[Arg::VecF32(&params), Arg::F32(sgd_eta), i, t, k]).unwrap();
    let stepped = lit_vec_f32(&outs[0]).unwrap();
    let disp: Vec<f32> = stepped.iter().zip(&params).map(|(a, b)| a - b).collect();
    let want_disp = exp.expect("sgd_disp_l2").unwrap().as_f64().unwrap();
    let disp_l2 = vecmath::nrm2(&disp);
    assert!(
        (disp_l2 - want_disp).abs() / want_disp < 1e-2,
        "sgd disp {disp_l2} vs jax {want_disp}"
    );

    // two AdamW steps on the same batch: loss at step 2 is f(x1) and the
    // total displacement ||x2 - x0|| must both track jax
    let adamw = rt.load_kind(&preset, "fo_adamw_step").unwrap();
    let mut x = params.clone();
    let mut mu = vec![0f32; meta.d_pad];
    let mut nu = vec![0f32; meta.d_pad];
    let mut loss2 = 0f64;
    for step_t in 1..=2 {
        let (i, t, k) = batch3();
        let outs = adamw
            .call(&[
                Arg::VecF32(&x),
                Arg::VecF32(&mu),
                Arg::VecF32(&nu),
                Arg::F32(step_t as f32),
                Arg::F32(adamw_eta),
                i,
                t,
                k,
            ])
            .unwrap();
        x = lit_vec_f32(&outs[0]).unwrap();
        mu = lit_vec_f32(&outs[1]).unwrap();
        nu = lit_vec_f32(&outs[2]).unwrap();
        loss2 = lit_f32(&outs[3]).unwrap() as f64;
    }
    let want_loss2 = exp.expect("adamw_loss2").unwrap().as_f64().unwrap();
    assert!(
        (loss2 - want_loss2).abs() < 1e-3 * want_loss2.abs().max(1.0),
        "adamw step-2 loss {loss2} vs jax {want_loss2}"
    );
    let adisp: Vec<f32> = x.iter().zip(&params).map(|(a, b)| a - b).collect();
    let want_adisp = exp.expect("adamw_disp_l2").unwrap().as_f64().unwrap();
    let adisp_l2 = vecmath::nrm2(&adisp);
    assert!(
        (adisp_l2 - want_adisp).abs() / want_adisp < 1e-2,
        "adamw disp {adisp_l2} vs jax {want_adisp}"
    );
}

// ---------------------------------------------------------------------------
// program semantics on the native backend
// ---------------------------------------------------------------------------

// ---------------------------------------------------------------------------
// session API: bind-once/run-many vs the legacy Program::call shim
// ---------------------------------------------------------------------------

#[test]
fn session_matches_legacy_program_call_bitwise() {
    // the redesign contract: a bound Session and the legacy load/call shim
    // must produce byte-identical outputs for the same program + args
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let init = rt.load_kind("nano", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
    let sample = rt.load_kind("nano", "sample_u").unwrap();
    let z = lit_vec_f32(&sample.call(&[Arg::I32(7)]).unwrap()[0]).unwrap();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let mut sampler = TrainSampler::new(gen.dataset(32, 1), meta.batch, meta.seq_len, 1, 0);
    let batch = sampler.next_batch();
    let dims = vec![meta.batch, meta.seq_len];

    // loss
    let legacy = rt.load_kind("nano", "loss").unwrap();
    let want = legacy
        .call(&[
            Arg::VecF32(&params),
            Arg::TensorI32(&batch.input_ids, dims.clone()),
            Arg::TensorI32(&batch.targets, dims.clone()),
            Arg::TensorF32(&batch.mask, dims.clone()),
        ])
        .unwrap();
    let mut sess = rt.bind_kind("nano", "loss").unwrap();
    let got = sess
        .run(&[
            Arg::VecF32(&params),
            Arg::TensorI32(&batch.input_ids, dims.clone()),
            Arg::TensorI32(&batch.targets, dims.clone()),
            Arg::TensorF32(&batch.mask, dims.clone()),
        ])
        .unwrap();
    assert_eq!(got, want.as_slice(), "session loss != legacy call loss");

    // two_point (run and the antithetic fast path)
    let legacy_tp = rt.load_kind("nano", "two_point").unwrap();
    let want = legacy_tp
        .call(&[
            Arg::VecF32(&params),
            Arg::VecF32(&z),
            Arg::F32(1e-3),
            Arg::TensorI32(&batch.input_ids, dims.clone()),
            Arg::TensorI32(&batch.targets, dims.clone()),
            Arg::TensorF32(&batch.mask, dims.clone()),
        ])
        .unwrap();
    let mut tp = rt.bind_kind("nano", "two_point").unwrap();
    let (lp, lm) = tp
        .two_point(&params, &z, 1e-3, &batch.input_ids, &batch.targets, &batch.mask)
        .unwrap();
    assert_eq!(lp as f32, lit_f32(&want[0]).unwrap());
    assert_eq!(lm as f32, lit_f32(&want[1]).unwrap());
}

#[test]
fn session_repeated_runs_replay_exactly() {
    // workspace-reuse invariant at the objective level: the same (params,
    // batch) evaluated over and over through one ModelObjective session
    // set must be bit-stable
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let batch = TrainSampler::new(gen.dataset(16, 3), meta.batch, meta.seq_len, 3, 0).next_batch();
    let mut obj = ModelObjective::new(
        &rt,
        "nano",
        Box::new(conmezo::objective::CyclicBatches { batches: vec![batch], i: 0 }),
    )
    .unwrap();
    let init = rt.load_kind("nano", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
    let sample = rt.load_kind("nano", "sample_u").unwrap();
    let z = lit_vec_f32(&sample.call(&[Arg::I32(9)]).unwrap()[0]).unwrap();
    let l0 = obj.loss(&params).unwrap();
    let p0 = obj.two_point(&params, &z, 1e-3).unwrap();
    for _ in 0..4 {
        assert_eq!(obj.loss(&params).unwrap(), l0);
        assert_eq!(obj.two_point(&params, &z, 1e-3).unwrap(), p0);
    }
    // 5 rounds of loss (1 eval) + two_point (2 evals)
    assert_eq!(obj.evals(), 15, "eval accounting must track the fast path");
}

#[test]
fn threaded_runtime_loss_is_bit_identical_to_single() {
    // end-to-end bit-identity of the ParallelPolicy plumbing: the small
    // preset has 512 forward rows, enough for the GEMM work gate to
    // actually spawn threads
    use conmezo::runtime::ParallelPolicy;
    let single = Runtime::native_with(ParallelPolicy::single());
    let meta = single.preset("small").unwrap().clone();
    let init = single.load_kind("small", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(2)]).unwrap()[0]).unwrap();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let batch = TrainSampler::new(gen.dataset(16, 2), meta.batch, meta.seq_len, 2, 0).next_batch();
    let dims = vec![meta.batch, meta.seq_len];
    let run = |rt: &Runtime| {
        let mut sess = rt.bind_kind("small", "loss").unwrap();
        let outs = sess
            .run(&[
                Arg::VecF32(&params),
                Arg::TensorI32(&batch.input_ids, dims.clone()),
                Arg::TensorI32(&batch.targets, dims.clone()),
                Arg::TensorF32(&batch.mask, dims.clone()),
            ])
            .unwrap();
        lit_f32(&outs[0]).unwrap()
    };
    let want = run(&single);
    for t in [2usize, 4, 8] {
        let rt_mt = Runtime::native_with(ParallelPolicy::from_count(t));
        assert_eq!(run(&rt_mt), want, "threads={t} diverged");
    }
}

#[test]
fn quad_programs_match_native_objective() {
    let rt = runtime();
    let prog = rt.load("quad_loss").unwrap();
    let mut native = NativeQuadratic::new(1000);
    let mut rng = conmezo::util::rng::Xoshiro256pp::seed_from_u64(3);
    let mut x = vec![0f32; 1000];
    rng.fill_normal_f32(&mut x);
    let outs = prog.call(&[Arg::VecF32(&x)]).unwrap();
    let got = lit_f32(&outs[0]).unwrap() as f64;
    let nat = native.loss(&x).unwrap();
    assert!((got - nat).abs() / nat.abs().max(1e-9) < 1e-4, "{got} vs {nat}");

    let grad_prog = rt.load("quad_grad").unwrap();
    let outs = grad_prog.call(&[Arg::VecF32(&x)]).unwrap();
    let got = lit_vec_f32(&outs[0]).unwrap();
    let mut g = vec![0f32; 1000];
    native.grad(&x, &mut g);
    for i in (0..1000).step_by(97) {
        let tol = 1e-4 * g[i].abs().max(1e-3);
        assert!((got[i] - g[i]).abs() < tol, "coord {i}: {} vs {}", got[i], g[i]);
    }
}

#[test]
fn init_program_deterministic_and_padded() {
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let init = rt.load_kind("nano", "init").unwrap();
    let a = lit_vec_f32(&init.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
    let b = lit_vec_f32(&init.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
    let c = lit_vec_f32(&init.call(&[Arg::I32(6)]).unwrap()[0]).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert_eq!(a.len(), meta.d_pad);
    assert!(a[meta.d_raw..].iter().all(|&v| v == 0.0), "pads must be zero");
}

#[test]
fn loss_program_is_batch_sensitive_and_finite() {
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let mut s1 = TrainSampler::new(gen.dataset(32, 1), meta.batch, meta.seq_len, 1, 0);
    let mut obj = ModelObjective::new(
        &rt,
        "nano",
        Box::new(TrainSampler::new(gen.dataset(32, 1), meta.batch, meta.seq_len, 1, 0)),
    )
    .unwrap();
    let init = rt.load_kind("nano", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
    let l1 = obj.loss(&params).unwrap();
    assert!(l1.is_finite() && l1 > 0.0);
    // fresh model ~ uniform prediction: loss ~ ln(vocab)
    assert!((l1 - (meta.vocab as f64).ln()).abs() < 0.7, "{l1}");
    obj.advance();
    let l2 = obj.loss(&params).unwrap();
    assert_ne!(l1, l2, "different batches must give different losses");
    let _ = s1.next_batch();
}

#[test]
fn fused_conmezo_exactly_matches_composed_host_path() {
    // THE equivalence, now exact: the native fused step program and the
    // composed path (host vecmath + two_point program) share the same
    // kernels, so driving both with the same direction must agree bitwise.
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let data = gen.dataset(32, 1);
    let mut sampler = TrainSampler::new(data.clone(), meta.batch, meta.seq_len, 1, 0);
    let batch = sampler.next_batch();

    let init = rt.load_kind("nano", "init").unwrap();
    let params0 = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
    let (theta, beta, eta, lam) = (1.35f32, 0.9f32, 1e-4f32, 1e-3f32);
    let seed = 77i32;

    // fused path
    let mut fused = FusedConMeZo::new(&rt, "nano", theta).unwrap();
    let mut p_fused = params0.clone();
    let stats = fused.step(&mut p_fused, &batch, seed, beta, eta, lam).unwrap();

    // composed path with the SAME direction: regenerate u via sample_u
    let sample_u = rt.load_kind("nano", "sample_u").unwrap();
    let u = lit_vec_f32(&sample_u.call(&[Arg::I32(seed)]).unwrap()[0]).unwrap();
    let m0 = u.clone(); // t=0: m <- u
    let mut z = vec![0f32; meta.d_pad];
    vecmath::cone_direction(&m0, &u, theta, meta.d_raw, &mut z);
    let mut obj = ModelObjective::new(
        &rt,
        "nano",
        Box::new(conmezo::objective::CyclicBatches { batches: vec![batch.clone()], i: 0 }),
    )
    .unwrap();
    let (lp, lm) = obj.two_point(&params0, &z, lam).unwrap();
    let g = ((lp - lm) / (2.0 * lam as f64)) as f32;
    let mut p_host = params0.clone();
    let mut m_host = m0;
    vecmath::zo_update(&mut p_host, &mut m_host, &z, g, eta, beta);

    assert_eq!(stats.proj_grad, g as f64, "fused and composed proj-grad must be identical");
    assert_eq!(p_fused, p_host, "fused and composed parameters must be bit-identical");
    assert_eq!(fused.m, m_host, "fused and composed momentum must be bit-identical");
    assert!(stats.loss.is_finite());
}

#[test]
fn fused_mezo_seed_replay_is_deterministic() {
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("rte").unwrap(), meta.vocab, meta.seq_len);
    let mut sampler = TrainSampler::new(gen.dataset(16, 2), meta.batch, meta.seq_len, 2, 0);
    let batch = sampler.next_batch();
    let init = rt.load_kind("nano", "init").unwrap();
    let params0 = lit_vec_f32(&init.call(&[Arg::I32(2)]).unwrap()[0]).unwrap();

    let mut a = FusedMezo::new(&rt, "nano").unwrap();
    let mut pa = params0.clone();
    a.step(&mut pa, &batch, 9, 1e-4, 1e-3).unwrap();
    let mut b = FusedMezo::new(&rt, "nano").unwrap();
    let mut pb = params0.clone();
    b.step(&mut pb, &batch, 9, 1e-4, 1e-3).unwrap();
    assert_eq!(pa, pb, "same seed must give bit-identical updates");
    let mut c = FusedMezo::new(&rt, "nano").unwrap();
    let mut pc = params0;
    c.step(&mut pc, &batch, 10, 1e-4, 1e-3).unwrap();
    assert_ne!(pa, pc);
}

#[test]
fn eval_logits_shape_and_candidates() {
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let prog = rt.load_kind("nano", "eval_logits").unwrap();
    let init = rt.load_kind("nano", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
    let ids = vec![1i32; meta.batch * meta.seq_len];
    let pos = vec![(meta.seq_len - 1) as i32; meta.batch];
    let outs = prog
        .call(&[
            Arg::VecF32(&params),
            Arg::TensorI32(&ids, vec![meta.batch, meta.seq_len]),
            Arg::TensorI32(&pos, vec![meta.batch]),
        ])
        .unwrap();
    let logits = lit_vec_f32(&outs[0]).unwrap();
    assert_eq!(logits.len(), meta.batch * meta.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn program_shape_validation_rejects_bad_args() {
    let rt = runtime();
    let prog = rt.load("quad_loss").unwrap();
    let too_short = vec![0f32; 10];
    let err = match prog.call(&[Arg::VecF32(&too_short)]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("short arg accepted"),
    };
    assert!(err.contains("shape mismatch"), "{err}");
    let err2 = match prog.call(&[]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("empty args accepted"),
    };
    assert!(err2.contains("expected 1 args"), "{err2}");
}

#[test]
fn backends_share_manifest_signatures() {
    // the native manifest mirrors aot.py's program signatures, so code
    // written against one backend calls the other unchanged
    let rt = runtime();
    let spec = rt.manifest().program("nano_conmezo_step").unwrap();
    let names: Vec<&str> = spec.inputs.iter().map(|i| i.name.as_str()).collect();
    assert_eq!(
        names,
        ["params", "m", "seed", "theta", "beta", "eta", "lam", "input_ids", "targets", "mask"]
    );
    assert_eq!(spec.outputs, ["params", "m", "loss_plus", "loss_minus", "proj_grad"]);
    let two = rt.manifest().program("nano_two_point").unwrap();
    assert_eq!(two.inputs[0].shape, vec![rt.preset("nano").unwrap().d_pad]);
    assert_eq!(two.outputs, ["loss_plus", "loss_minus"]);
}

// ---------------------------------------------------------------------------
// PJRT-only: AOT artifacts + cross-backend parity
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_parity {
    use super::*;

    fn pjrt_runtime() -> Option<Runtime> {
        match Runtime::from_name("pjrt") {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping pjrt parity test (no artifacts): {e}");
                None
            }
        }
    }

    #[test]
    fn pjrt_and_native_loss_agree() {
        let Some(pjrt) = pjrt_runtime() else { return };
        let native = Runtime::native();
        let meta = native.preset("nano").unwrap().clone();
        let init = native.load_kind("nano", "init").unwrap();
        let params = lit_vec_f32(&init.call(&[Arg::I32(4)]).unwrap()[0]).unwrap();
        let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
        let mut sampler = TrainSampler::new(gen.dataset(16, 4), meta.batch, meta.seq_len, 4, 0);
        let batch = sampler.next_batch();
        let dims = vec![meta.batch, meta.seq_len];
        let call = |rt: &Runtime| -> f64 {
            let prog = rt.load_kind("nano", "loss").unwrap();
            let outs = prog
                .call(&[
                    Arg::VecF32(&params),
                    Arg::TensorI32(&batch.input_ids, dims.clone()),
                    Arg::TensorI32(&batch.targets, dims.clone()),
                    Arg::TensorF32(&batch.mask, dims.clone()),
                ])
                .unwrap();
            lit_f32(&outs[0]).unwrap() as f64
        };
        let (ln, lp) = (call(&native), call(&pjrt));
        assert!((ln - lp).abs() < 2e-3 * lp.abs().max(1.0), "native {ln} vs pjrt {lp}");
    }

    #[test]
    fn pjrt_quad_matches_native_objective() {
        let Some(rt) = pjrt_runtime() else { return };
        let prog = rt.load("quad_loss").unwrap();
        let mut native = NativeQuadratic::new(1000);
        let x = vec![0.5f32; 1000];
        let outs = prog.call(&[Arg::VecF32(&x)]).unwrap();
        let hlo = lit_f32(&outs[0]).unwrap() as f64;
        let nat = native.loss(&x).unwrap();
        assert!((hlo - nat).abs() / nat.abs().max(1e-9) < 1e-4, "{hlo} vs {nat}");
    }

    #[test]
    fn pjrt_fused_conmezo_matches_composed_host_path() {
        // the tolerance-based twin of the native bitwise test: the fused
        // HLO step (Pallas kernels inside) and the composed path must
        // implement the same Algorithm 1 update when driven with the same
        // direction (regenerated via the artifacts' sample_u program)
        let Some(rt) = pjrt_runtime() else { return };
        let meta = rt.preset("nano").unwrap().clone();
        let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
        let mut sampler =
            TrainSampler::new(gen.dataset(32, 1), meta.batch, meta.seq_len, 1, 0);
        let batch = sampler.next_batch();

        let init = rt.load_kind("nano", "init").unwrap();
        let params0 = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
        let (theta, beta, eta, lam) = (1.35f32, 0.9f32, 1e-4f32, 1e-3f32);
        let seed = 77i32;

        let mut fused = FusedConMeZo::new(&rt, "nano", theta).unwrap();
        let mut p_fused = params0.clone();
        let stats = fused.step(&mut p_fused, &batch, seed, beta, eta, lam).unwrap();

        let sample_u = rt.load_kind("nano", "sample_u").unwrap();
        let u = lit_vec_f32(&sample_u.call(&[Arg::I32(seed)]).unwrap()[0]).unwrap();
        let m0 = u.clone();
        let mut z = vec![0f32; meta.d_pad];
        vecmath::cone_direction(&m0, &u, theta, meta.d_raw, &mut z);
        let mut obj = ModelObjective::new(
            &rt,
            "nano",
            Box::new(conmezo::objective::CyclicBatches { batches: vec![batch.clone()], i: 0 }),
        )
        .unwrap();
        let (lp, lm) = obj.two_point(&params0, &z, lam).unwrap();
        let g = ((lp - lm) / (2.0 * lam as f64)) as f32;
        let mut p_host = params0.clone();
        let mut m_host = m0;
        vecmath::zo_update(&mut p_host, &mut m_host, &z, g, eta, beta);

        assert!(
            (stats.proj_grad - g as f64).abs() < 5e-3 * (g as f64).abs().max(1.0),
            "proj grad: fused {} vs composed {g}",
            stats.proj_grad
        );
        let mut max_rel = 0f64;
        for i in (0..meta.d_pad).step_by(101) {
            let diff = (p_fused[i] - p_host[i]).abs() as f64;
            max_rel = max_rel.max(diff / (p_host[i].abs().max(1e-3) as f64));
        }
        assert!(max_rel < 1e-2, "fused vs composed params diverge: {max_rel}");
    }
}
