//! Integration tests over the PJRT runtime + AOT artifacts: numerics of
//! loaded programs against golden values and cross-implementation
//! equivalences (fused HLO vs composed host path, HLO quadratic vs native).
//!
//! These tests need `artifacts/` (run `make artifacts` first); they are
//! skipped gracefully when absent so `cargo test` works on a fresh clone.

use conmezo::coordinator::{FusedConMeZo, FusedMezo};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::{BatchSource, HloObjective, NativeQuadratic, Objective};
use conmezo::runtime::{lit_f32, lit_vec_f32, Arg, Runtime};
use conmezo::vecmath;

fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn quad_hlo_matches_native() {
    let Some(rt) = runtime() else { return };
    let prog = rt.load("quad_loss").unwrap();
    let mut native = NativeQuadratic::new(1000);
    let mut rng = conmezo::util::rng::Xoshiro256pp::seed_from_u64(3);
    let mut x = vec![0f32; 1000];
    rng.fill_normal_f32(&mut x);
    let outs = prog.call(&[Arg::VecF32(&x)]).unwrap();
    let hlo = lit_f32(&outs[0]).unwrap() as f64;
    let nat = native.loss(&x).unwrap();
    assert!((hlo - nat).abs() / nat.abs().max(1e-9) < 1e-4, "{hlo} vs {nat}");
}

#[test]
fn quad_grad_matches_native() {
    let Some(rt) = runtime() else { return };
    let prog = rt.load("quad_grad").unwrap();
    let native = NativeQuadratic::new(1000);
    let x = vec![0.5f32; 1000];
    let outs = prog.call(&[Arg::VecF32(&x)]).unwrap();
    let hlo = lit_vec_f32(&outs[0]).unwrap();
    let mut g = vec![0f32; 1000];
    native.grad(&x, &mut g);
    for i in (0..1000).step_by(97) {
        // f32 pow chains differ slightly between XLA and the host sigmas
        let tol = 1e-4 * g[i].abs().max(1e-3);
        assert!((hlo[i] - g[i]).abs() < tol, "coord {i}: {} vs {}", hlo[i], g[i]);
    }
}

#[test]
fn init_program_deterministic_and_padded() {
    let Some(rt) = runtime() else { return };
    let meta = rt.preset("nano").unwrap().clone();
    let init = rt.load_kind("nano", "init").unwrap();
    let a = lit_vec_f32(&init.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
    let b = lit_vec_f32(&init.call(&[Arg::I32(5)]).unwrap()[0]).unwrap();
    let c = lit_vec_f32(&init.call(&[Arg::I32(6)]).unwrap()[0]).unwrap();
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert_eq!(a.len(), meta.d_pad);
    assert!(a[meta.d_raw..].iter().all(|&v| v == 0.0), "pads must be zero");
}

#[test]
fn loss_program_is_batch_sensitive_and_finite() {
    let Some(rt) = runtime() else { return };
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let mut s1 = TrainSampler::new(gen.dataset(32, 1), meta.batch, meta.seq_len, 1, 0);
    let mut obj = HloObjective::new(&rt, "nano", Box::new(TrainSampler::new(gen.dataset(32, 1), meta.batch, meta.seq_len, 1, 0))).unwrap();
    let init = rt.load_kind("nano", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
    let l1 = obj.loss(&params).unwrap();
    assert!(l1.is_finite() && l1 > 0.0);
    // fresh model ~ uniform prediction: loss ~ ln(vocab)
    assert!((l1 - (meta.vocab as f64).ln()).abs() < 0.7, "{l1}");
    obj.advance();
    let l2 = obj.loss(&params).unwrap();
    assert_ne!(l1, l2, "different batches must give different losses");
    let _ = s1.next_batch();
}

#[test]
fn fused_conmezo_matches_composed_host_path() {
    // THE equivalence: the fused HLO step (Pallas kernels inside) and the
    // composed path (host vecmath + two_point program) implement the same
    // Algorithm 1 update when driven with the same direction.
    let Some(rt) = runtime() else { return };
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let data = gen.dataset(32, 1);
    let mut sampler = TrainSampler::new(data.clone(), meta.batch, meta.seq_len, 1, 0);
    let batch = sampler.next_batch();

    let init = rt.load_kind("nano", "init").unwrap();
    let params0 = lit_vec_f32(&init.call(&[Arg::I32(1)]).unwrap()[0]).unwrap();
    let (theta, beta, eta, lam) = (1.35f32, 0.9f32, 1e-4f32, 1e-3f32);
    let seed = 77i32;

    // fused path
    let mut fused = FusedConMeZo::new(&rt, "nano", theta).unwrap();
    let mut p_fused = params0.clone();
    let stats = fused.step(&mut p_fused, &batch, seed, beta, eta, lam).unwrap();

    // composed path with the SAME direction: regenerate u via sample_u
    let sample_u = rt.load_kind("nano", "sample_u").unwrap();
    let u = lit_vec_f32(&sample_u.call(&[Arg::I32(seed)]).unwrap()[0]).unwrap();
    let m0 = u.clone(); // t=0: m <- u
    let mut z = vec![0f32; meta.d_pad];
    vecmath::cone_direction(&m0, &u, theta, meta.d_raw, &mut z);
    let mut obj = HloObjective::new(
        &rt,
        "nano",
        Box::new(conmezo::objective::CyclicBatches { batches: vec![batch.clone()], i: 0 }),
    )
    .unwrap();
    let (lp, lm) = obj.two_point(&params0, &z, lam).unwrap();
    let g = ((lp - lm) / (2.0 * lam as f64)) as f32;
    let mut p_host = params0.clone();
    let mut m_host = m0;
    vecmath::zo_update(&mut p_host, &mut m_host, &z, g, eta, beta);

    assert!(
        (stats.proj_grad - g as f64).abs() < 5e-3 * g.abs().max(1.0) as f64,
        "proj grad: fused {} vs composed {g}",
        stats.proj_grad
    );
    let mut max_rel = 0f64;
    for i in (0..meta.d_pad).step_by(101) {
        let diff = (p_fused[i] - p_host[i]).abs() as f64;
        max_rel = max_rel.max(diff / p_host[i].abs().max(1e-3) as f64);
    }
    assert!(max_rel < 1e-2, "fused vs composed params diverge: {max_rel}");
}

#[test]
fn fused_mezo_seed_replay_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("rte").unwrap(), meta.vocab, meta.seq_len);
    let mut sampler = TrainSampler::new(gen.dataset(16, 2), meta.batch, meta.seq_len, 2, 0);
    let batch = sampler.next_batch();
    let init = rt.load_kind("nano", "init").unwrap();
    let params0 = lit_vec_f32(&init.call(&[Arg::I32(2)]).unwrap()[0]).unwrap();

    let mut a = FusedMezo::new(&rt, "nano").unwrap();
    let mut pa = params0.clone();
    a.step(&mut pa, &batch, 9, 1e-4, 1e-3).unwrap();
    let mut b = FusedMezo::new(&rt, "nano").unwrap();
    let mut pb = params0.clone();
    b.step(&mut pb, &batch, 9, 1e-4, 1e-3).unwrap();
    assert_eq!(pa, pb, "same seed must give bit-identical updates");
    let mut c = FusedMezo::new(&rt, "nano").unwrap();
    let mut pc = params0;
    c.step(&mut pc, &batch, 10, 1e-4, 1e-3).unwrap();
    assert_ne!(pa, pc);
}

#[test]
fn eval_logits_shape_and_candidates() {
    let Some(rt) = runtime() else { return };
    let meta = rt.preset("nano").unwrap().clone();
    let prog = rt.load_kind("nano", "eval_logits").unwrap();
    let init = rt.load_kind("nano", "init").unwrap();
    let params = lit_vec_f32(&init.call(&[Arg::I32(3)]).unwrap()[0]).unwrap();
    let ids = vec![1i32; meta.batch * meta.seq_len];
    let pos = vec![(meta.seq_len - 1) as i32; meta.batch];
    let outs = prog
        .call(&[
            Arg::VecF32(&params),
            Arg::TensorI32(&ids, vec![meta.batch, meta.seq_len]),
            Arg::TensorI32(&pos, vec![meta.batch]),
        ])
        .unwrap();
    let logits = lit_vec_f32(&outs[0]).unwrap();
    assert_eq!(logits.len(), meta.batch * meta.vocab);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn program_shape_validation_rejects_bad_args() {
    let Some(rt) = runtime() else { return };
    let prog = rt.load("quad_loss").unwrap();
    let too_short = vec![0f32; 10];
    let err = match prog.call(&[Arg::VecF32(&too_short)]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("short arg accepted"),
    };
    assert!(err.contains("shape mismatch"), "{err}");
    let err2 = match prog.call(&[]) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("empty args accepted"),
    };
    assert!(err2.contains("expected 1 args"), "{err2}");
}
