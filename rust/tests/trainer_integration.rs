//! Integration tests over the full training stack: Trainer drives loss
//! down, checkpoint save/resume equivalence, distributed-vs-single-node
//! equivalence on the HLO objective, and property-based coordinator
//! invariants.

use conmezo::checkpoint::Checkpoint;
use conmezo::coordinator::{DistHypers, LocalCluster, Mode, TrainConfig, Trainer, ZoWorker};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::HloObjective;
use conmezo::optimizer::BetaSchedule;
use conmezo::runtime::{lit_vec_f32, Arg, Runtime};
use conmezo::testing::{property, NormalVec, UsizeRange};

fn runtime() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping integration test (no artifacts): {e}");
            None
        }
    }
}

fn quick_cfg(opt: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::preset("nano", "sst2", opt);
    cfg.steps = steps;
    cfg.eta = 3e-4;
    cfg.eval_every = steps;
    cfg.log_every = steps;
    cfg
}

#[test]
fn trainer_drives_loss_down_fused_and_composed() {
    let Some(rt) = runtime() else { return };
    for (opt, mode) in [("conmezo", Mode::Fused), ("mezo", Mode::Fused), ("zo_adamm", Mode::Composed)] {
        let mut cfg = quick_cfg(opt, 400);
        cfg.mode = mode;
        if opt == "zo_adamm" {
            cfg.eta = 1e-3;
        }
        let mut tr = Trainer::new(&rt, cfg).unwrap();
        let first = tr.step(0).unwrap();
        let summary = tr.run().unwrap();
        assert!(
            summary.final_loss < first,
            "{opt}: loss did not decrease ({} -> {})",
            first,
            summary.final_loss
        );
    }
}

#[test]
fn fo_adamw_solves_task() {
    let Some(rt) = runtime() else { return };
    let mut cfg = quick_cfg("adamw", 200);
    cfg.eta = 1e-3;
    cfg.eval_every = 100;
    let summary = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(summary.final_accuracy > 0.9, "adamw acc {}", summary.final_accuracy);
}

#[test]
fn run_is_deterministic_per_seed() {
    let Some(rt) = runtime() else { return };
    let run = |seed: u64| {
        let mut cfg = quick_cfg("conmezo", 60);
        cfg.seed = seed;
        Trainer::new(&rt, cfg).unwrap().run().unwrap().final_loss
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}

#[test]
fn checkpoint_resume_equivalence() {
    // train 40 steps straight == train 20, checkpoint, reload, train 20:
    // parameter state round-trips exactly; the remaining steps use the same
    // per-step seeds because seeds derive from (run_seed, t)
    let Some(rt) = runtime() else { return };
    let dir = std::env::temp_dir().join("conmezo_it_ckpt");
    let path = dir.join("mid.ckpt");

    let mut straight = Trainer::new(&rt, quick_cfg("mezo", 1)).unwrap();
    for t in 0..40 {
        straight.step(t).unwrap();
    }

    let mut first = Trainer::new(&rt, quick_cfg("mezo", 1)).unwrap();
    for t in 0..20 {
        first.step(t).unwrap();
    }
    first.save_checkpoint(&path, 20).unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    let mut resumed = Trainer::new(&rt, quick_cfg("mezo", 1)).unwrap();
    resumed.params = ck.get("params").unwrap().to_vec();
    // also rewind the data stream by replaying the first 20 batches
    for t in 0..20 {
        let _ = t;
    }
    // NOTE: mezo's direction depends only on (run_seed, t); the batch
    // stream of `resumed` is at position 0 though, so exact equality holds
    // only for the parameter state at the checkpoint itself:
    assert_eq!(resumed.params, first.params);
    // and the checkpoint file round-trips the exact bytes
    let ck2 = Checkpoint::load(&path).unwrap();
    assert_eq!(ck2.get("params").unwrap(), first.params.as_slice());
    assert_eq!(ck2.step, 20);
}

#[test]
fn distributed_hlo_workers_stay_identical_and_learn() {
    let Some(rt) = runtime() else { return };
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let init = rt.load_kind("nano", "init").unwrap();
    let x0 = lit_vec_f32(&init.call(&[Arg::I32(9)]).unwrap()[0]).unwrap();

    let mut workers = Vec::new();
    for id in 0..3u32 {
        let sampler = TrainSampler::new(gen.dataset(64, 9), meta.batch, meta.seq_len, 9, id as u64);
        let obj = HloObjective::new(&rt, "nano", Box::new(sampler)).unwrap();
        workers.push(ZoWorker::new(id, x0.clone(), Box::new(obj)));
    }
    let mut cluster = LocalCluster::new(workers, 11);
    let hypers = DistHypers { theta: 1.35, eta: 3e-4, lam: 1e-3 };
    let summary = cluster.run(150, hypers, &BetaSchedule::Constant(0.99), 0).unwrap();
    assert!(cluster.replicas_identical(), "replicas diverged on HLO objective");
    let first = summary.loss_curve.first().unwrap().1;
    let last = summary.loss_curve.last().unwrap().1;
    assert!(last < first, "distributed loss did not decrease: {first} -> {last}");
    // O(1) communication
    assert!(summary.wire_bytes < 150 * 3 * 200, "wire bytes too high: {}", summary.wire_bytes);
}

#[test]
fn evaluator_accuracy_on_oracle_params() {
    // sanity: the Evaluator must report ~100% when the "model" is replaced
    // by AdamW-trained parameters that solve the task
    let Some(rt) = runtime() else { return };
    let mut cfg = quick_cfg("adamw", 250);
    cfg.eta = 1e-3;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    for t in 0..250 {
        tr.step(t).unwrap();
    }
    let r = tr.evaluate().unwrap();
    assert!(r.accuracy() > 0.9, "{}", r.accuracy());
    assert!(r.macro_f1 > 0.85, "{}", r.macro_f1);
}

// ---------------------------------------------------------------------------
// property-based coordinator invariants (no artifacts needed)
// ---------------------------------------------------------------------------

#[test]
fn prop_cone_norm_is_scale_invariant_in_m() {
    // ||z|| must not depend on ||m|| (only on the direction of m)
    let g = NormalVec { min_len: 64, max_len: 512 };
    property("cone-scale-invariance", &g, 32, |u| {
        let d = u.len();
        let mut m: Vec<f32> = u.iter().map(|x| x * 0.7 + 0.1).collect();
        let mut z1 = vec![0f32; d];
        conmezo::vecmath::cone_direction(&m, u, 1.2, d, &mut z1);
        for v in m.iter_mut() {
            *v *= 1000.0;
        }
        let mut z2 = vec![0f32; d];
        conmezo::vecmath::cone_direction(&m, u, 1.2, d, &mut z2);
        z1.iter().zip(&z2).all(|(a, b)| (a - b).abs() <= 1e-3 * a.abs().max(1.0))
    });
}

#[test]
fn prop_seed_replay_bit_identical() {
    let g = UsizeRange(1, 10_000);
    property("seed-replay", &g, 64, |&t| {
        let mut a = vec![0f32; 256];
        let mut b = vec![0f32; 256];
        conmezo::optimizer::sample_direction(&mut a, 250, 0xFEED, t);
        conmezo::optimizer::sample_direction(&mut b, 250, 0xFEED, t);
        a == b && a[250..].iter().all(|&v| v == 0.0)
    });
}

#[test]
fn prop_batcher_never_drops_or_duplicates_loss_mass() {
    let g = UsizeRange(1, 8);
    property("batch-loss-mass", &g, 32, |&n| {
        let gen = TaskGen::new(spec("trec").unwrap(), 256, 32);
        let data = gen.dataset(n, n as u64);
        let refs: Vec<&conmezo::data::Example> = data.iter().collect();
        let b = conmezo::data::finetune_batch(&refs, 8, 32);
        // exactly one unit of loss mass per example, none for pad rows
        (b.mask.iter().sum::<f32>() as usize) == n
    });
}
