//! Integration tests over the full training stack on the NativeBackend:
//! Trainer drives loss down (fused and composed engines), deterministic
//! replay, checkpoint round-trip, distributed-vs-single-node equivalence on
//! the transformer objective, a tiny-preset end-to-end run, the first-order
//! baselines + pretrain -> finetune warm-start pipeline (native reverse-mode
//! autograd), and property-based coordinator invariants. No Python, no XLA,
//! no artifacts.
//!
//! Descent thresholds are calibrated against a numpy simulation of the
//! exact native math (see python/compile/gen_fixtures.py for the mirrored
//! PRNG): conmezo@3e-4 drops ~3.9 -> ~1.1 over 400 nano/sst2 steps,
//! zo_adamm@1e-3 ~3.9 -> ~2.2 over 300, the 3-worker cluster ~4.2 -> ~3.1
//! over 150. The `- 0.3`/`- 0.5` margins below sit far inside those gaps.
//!
//! The PJRT twins of the first-order tests remain feature-gated below and
//! now serve as cross-backend checks rather than the only FO coverage.

use conmezo::checkpoint::Checkpoint;
use conmezo::coordinator::{DistHypers, LocalCluster, Mode, TrainConfig, Trainer, ZoWorker};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::ModelObjective;
use conmezo::optimizer::BetaSchedule;
use conmezo::runtime::{lit_vec_f32, Arg, Runtime};
use conmezo::testing::{property, NormalVec, UsizeRange};

fn runtime() -> Runtime {
    Runtime::native()
}

fn quick_cfg(opt: &str, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig::preset("nano", "sst2", opt);
    cfg.steps = steps;
    cfg.eta = 3e-4;
    cfg.eval_every = steps;
    cfg.log_every = (steps / 8).max(1);
    cfg
}

#[test]
fn trainer_drives_loss_down_fused_and_composed() {
    let rt = runtime();
    for (opt, mode, eta, steps) in [
        ("conmezo", Mode::Fused, 3e-4f32, 400usize),
        ("mezo", Mode::Fused, 1e-3, 400),
        ("zo_adamm", Mode::Composed, 1e-3, 300),
    ] {
        let mut cfg = quick_cfg(opt, steps);
        cfg.mode = mode;
        cfg.eta = eta;
        let mut tr = Trainer::new(&rt, cfg).unwrap();
        let summary = tr.run().unwrap();
        let first = summary.loss_curve.first().unwrap().1;
        let last = summary.loss_curve.last().unwrap().1;
        assert!(
            last < first - 0.5,
            "{opt}: loss did not decrease enough ({first:.4} -> {last:.4})"
        );
        assert!(last.is_finite() && last > 0.0, "{opt}: {last}");
        assert_eq!(summary.evals_used, 2 * steps as u64, "{opt}");
    }
}

#[test]
fn run_is_deterministic_per_seed() {
    let rt = runtime();
    let run = |seed: u64| {
        let mut cfg = quick_cfg("conmezo", 60);
        cfg.seed = seed;
        Trainer::new(&rt, cfg).unwrap().run().unwrap().final_loss
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}

#[test]
fn checkpoint_resume_equivalence() {
    // train 40 steps straight == train 20, checkpoint, reload: parameter
    // state round-trips exactly; per-step seeds derive from (run_seed, t)
    let rt = runtime();
    let dir = std::env::temp_dir().join("conmezo_it_ckpt");
    let path = dir.join("mid.ckpt");

    let mut straight = Trainer::new(&rt, quick_cfg("mezo", 1)).unwrap();
    for t in 0..40 {
        straight.step(t).unwrap();
    }

    let mut first = Trainer::new(&rt, quick_cfg("mezo", 1)).unwrap();
    for t in 0..20 {
        first.step(t).unwrap();
    }
    first.save_checkpoint(&path, 20).unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    let mut resumed = Trainer::new(&rt, quick_cfg("mezo", 1)).unwrap();
    resumed.params = ck.get("params").unwrap().to_vec();
    assert_eq!(resumed.params, first.params);
    // and the checkpoint file round-trips the exact bytes
    let ck2 = Checkpoint::load(&path).unwrap();
    assert_eq!(ck2.get("params").unwrap(), first.params.as_slice());
    assert_eq!(ck2.step, 20);
}

#[test]
fn distributed_workers_stay_identical_and_learn() {
    // replicas share ONE bound two_point session per process (one forward
    // scratch, one WorkerPool) via model_workers_shared
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let init = rt.load_kind("nano", "init").unwrap();
    let x0 = lit_vec_f32(&init.call(&[Arg::I32(9)]).unwrap()[0]).unwrap();

    let samplers: Vec<Box<dyn conmezo::objective::BatchSource>> = (0..3u64)
        .map(|id| {
            Box::new(TrainSampler::new(gen.dataset(64, 9), meta.batch, meta.seq_len, 9, id))
                as Box<dyn conmezo::objective::BatchSource>
        })
        .collect();
    let workers = conmezo::coordinator::model_workers_shared(&rt, "nano", &x0, samplers).unwrap();
    let mut cluster = LocalCluster::new(workers, 11);
    let hypers = DistHypers { theta: 1.35, eta: 3e-4, lam: 1e-3 };
    let summary = cluster.run(150, hypers, &BetaSchedule::Constant(0.99), 0).unwrap();
    assert!(cluster.replicas_identical(), "replicas diverged on the model objective");
    let first = summary.loss_curve.first().unwrap().1;
    let last = summary.loss_curve.last().unwrap().1;
    assert!(last < first - 0.3, "distributed loss did not decrease: {first} -> {last}");
    // O(1) communication
    assert!(summary.wire_bytes < 150 * 3 * 200, "wire bytes too high: {}", summary.wire_bytes);
}

#[test]
fn shared_session_workers_match_private_session_workers() {
    // THE sharing invariant: a cluster whose replicas share one bound
    // session pair must be bit-identical, step for step, to one where
    // every replica binds its own sessions — session workspaces carry no
    // state across calls
    let rt = runtime();
    let meta = rt.preset("nano").unwrap().clone();
    let gen = TaskGen::new(spec("sst2").unwrap(), meta.vocab, meta.seq_len);
    let init = rt.load_kind("nano", "init").unwrap();
    let x0 = lit_vec_f32(&init.call(&[Arg::I32(13)]).unwrap()[0]).unwrap();
    let sampler = |id: u64| {
        Box::new(TrainSampler::new(gen.dataset(64, 13), meta.batch, meta.seq_len, 13, id))
            as Box<dyn conmezo::objective::BatchSource>
    };

    let shared = conmezo::coordinator::model_workers_shared(
        &rt,
        "nano",
        &x0,
        (0..3).map(|id| sampler(id as u64)).collect(),
    )
    .unwrap();
    let mut shared_cluster = LocalCluster::new(shared, 17);

    let mut private = Vec::new();
    for id in 0..3u32 {
        let obj = ModelObjective::new(&rt, "nano", sampler(id as u64)).unwrap();
        private.push(ZoWorker::new(id, x0.clone(), Box::new(obj)));
    }
    let mut private_cluster = LocalCluster::new(private, 17);

    let hypers = DistHypers { theta: 1.35, eta: 3e-4, lam: 1e-3 };
    shared_cluster.run(40, hypers, &BetaSchedule::Constant(0.99), 0).unwrap();
    private_cluster.run(40, hypers, &BetaSchedule::Constant(0.99), 0).unwrap();
    assert!(shared_cluster.replicas_identical());
    for (a, b) in shared_cluster.workers.iter().zip(&private_cluster.workers) {
        assert_eq!(a.x, b.x, "shared-session replica diverged from private-session replica");
        assert_eq!(a.m, b.m);
    }
}

#[test]
fn tiny_preset_trains_end_to_end() {
    // the acceptance workload: a full Trainer run on the tiny preset with
    // eval, entirely on the native backend
    let rt = runtime();
    let mut cfg = TrainConfig::preset("tiny", "sst2", "conmezo");
    cfg.steps = 24;
    cfg.eta = 3e-4;
    cfg.eval_every = 12;
    cfg.log_every = 6;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let summary = tr.run().unwrap();
    assert!(summary.final_loss.is_finite());
    // fresh tiny model: loss near ln(256) = 5.55, and never exploding
    assert!(summary.final_loss > 1.0 && summary.final_loss < 7.0, "{}", summary.final_loss);
    assert_eq!(summary.eval_curve.len(), 2);
    let acc = summary.final_accuracy;
    assert!((0.0..=1.0).contains(&acc), "{acc}");
    assert!(summary.steps_per_sec > 0.0);
    assert!(summary.peak_mem_mib > 0.0);
}

#[test]
fn evaluator_scores_are_well_formed() {
    let rt = runtime();
    let tr = Trainer::new(&rt, quick_cfg("conmezo", 10)).unwrap();
    let r = tr.evaluate().unwrap();
    assert_eq!(r.total, 128);
    assert!((0.0..=1.0).contains(&r.accuracy()));
    assert!(r.macro_f1.is_nan() || (0.0..=1.0).contains(&r.macro_f1));
}

#[test]
fn native_fo_adamw_solves_task() {
    // first-order AdamW now runs on the native backend via the reverse-mode
    // autograd pass — and converges like the paper's FO reference
    let rt = runtime();
    let mut cfg = quick_cfg("adamw", 200);
    cfg.eta = 1e-3;
    cfg.eval_every = 100;
    let summary = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    assert!(summary.final_accuracy > 0.9, "adamw acc {}", summary.final_accuracy);
    let first = summary.loss_curve.first().unwrap().1;
    let last = summary.loss_curve.last().unwrap().1;
    assert!(last < first - 0.5, "adamw loss {first:.3} -> {last:.3}");
}

#[test]
fn native_fo_sgd_descends() {
    let rt = runtime();
    let mut cfg = quick_cfg("sgd", 120);
    cfg.eta = 3e-2;
    let summary = Trainer::new(&rt, cfg).unwrap().run().unwrap();
    let first = summary.loss_curve.first().unwrap().1;
    let last = summary.loss_curve.last().unwrap().1;
    assert!(last < first - 0.3, "sgd loss {first:.3} -> {last:.3}");
}

#[test]
fn pretrain_then_conmezo_finetune_end_to_end() {
    // the acceptance pipeline, fully offline: AdamW pretraining on the
    // mixed synthetic corpus (native backprop) -> checkpoint -> ConMeZO
    // few-shot finetune warm-started from it, with the Fig. 6 cos^2 probe
    let rt = runtime();
    // per-process dir: concurrent runs on one machine must not share it
    let dir = std::env::temp_dir().join(format!("conmezo_it_pretrain_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pretrained_nano.ckpt");
    let _ = std::fs::remove_file(&path);
    let curve = conmezo::coordinator::pretrain(&rt, "nano", 80, 1e-3, 0.3, 7, &path).unwrap();
    assert!(path.exists(), "pretrain must write the checkpoint");
    let first = curve.first().unwrap().1;
    let last = curve.last().unwrap().1;
    assert!(last < first, "pretraining did not reduce loss: {first:.3} -> {last:.3}");

    let mut cfg = quick_cfg("conmezo", 40);
    cfg.init_from = Some(path);
    cfg.probe_cos2 = true;
    cfg.eval_every = 20;
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    let summary = tr.run().unwrap();
    assert!(summary.final_loss.is_finite() && summary.final_loss > 0.0);
    assert!((0.0..=1.0).contains(&summary.final_accuracy));
    assert!(!summary.cos2_curve.is_empty(), "probe_cos2 must record the alignment curve");
    for (_, c) in &summary.cos2_curve {
        assert!((0.0..=1.0).contains(c), "cos^2 out of range: {c}");
    }
}

#[test]
fn step_trace_jsonl_round_trips_with_history() {
    // ISSUE-7 acceptance: train with --trace, parse every JSONL line back,
    // and verify it matches the in-memory history bit-for-bit (floats are
    // emitted shortest-round-trip).
    let rt = runtime();
    let dir = std::env::temp_dir().join(format!("conmezo_it_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    let _ = std::fs::remove_file(&path);

    let steps = 30usize;
    let mut cfg = quick_cfg("conmezo", steps);
    cfg.trace = Some(path.clone());
    let mut tr = Trainer::new(&rt, cfg).unwrap();
    tr.run().unwrap();

    let history = tr.trace_history().to_vec();
    assert_eq!(history.len(), steps, "one record per step");
    let parsed = conmezo::telemetry::read_trace(&path).unwrap();
    assert_eq!(parsed.len(), steps);
    let mut cos_seen = 0usize;
    for (t, (mem, disk)) in history.iter().zip(&parsed).enumerate() {
        assert_eq!(disk.step, t as u64);
        assert_eq!(disk.seed, mem.seed);
        assert_eq!(disk.seed, Trainer::step_seed(42, t) as i64, "seed not replayable");
        assert_eq!(disk.loss.to_bits(), mem.loss.to_bits(), "step {t}: loss did not round-trip");
        assert_eq!(disk.proj_grad.to_bits(), mem.proj_grad.to_bits(), "step {t}: g did not round-trip");
        assert_eq!(disk.loss_plus.to_bits(), mem.loss_plus.to_bits());
        assert_eq!(disk.loss_minus.to_bits(), mem.loss_minus.to_bits());
        assert!((mem.loss - 0.5 * (mem.loss_plus + mem.loss_minus)).abs() < 1e-9);
        if mem.cos_zm.is_finite() {
            cos_seen += 1;
            assert!((-1.0..=1.0).contains(&mem.cos_zm), "step {t}: cos_zm {}", mem.cos_zm);
            assert_eq!(disk.cos_zm.to_bits(), mem.cos_zm.to_bits());
        } else {
            assert!(disk.cos_zm.is_nan(), "null must parse back to NaN");
        }
        assert!(disk.wall_s >= 0.0);
        assert_eq!(disk.eta as f32, 3e-4);
    }
    // tracing turned on the cos(z, m) reconstruction in the fused engine
    assert!(cos_seen >= steps - 2, "cos_zm missing from {}/{steps} steps", steps - cos_seen);
    // and the runtime registry counted every trainer step
    assert_eq!(rt.telemetry().unwrap().steps.get(), steps as u64);
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------------------
// property-based coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_cone_norm_is_scale_invariant_in_m() {
    // ||z|| must not depend on ||m|| (only on the direction of m)
    let g = NormalVec { min_len: 64, max_len: 512 };
    property("cone-scale-invariance", &g, 32, |u| {
        let d = u.len();
        let mut m: Vec<f32> = u.iter().map(|x| x * 0.7 + 0.1).collect();
        let mut z1 = vec![0f32; d];
        conmezo::vecmath::cone_direction(&m, u, 1.2, d, &mut z1);
        for v in m.iter_mut() {
            *v *= 1000.0;
        }
        let mut z2 = vec![0f32; d];
        conmezo::vecmath::cone_direction(&m, u, 1.2, d, &mut z2);
        z1.iter().zip(&z2).all(|(a, b)| (a - b).abs() <= 1e-3 * a.abs().max(1.0))
    });
}

#[test]
fn prop_seed_replay_bit_identical() {
    let g = UsizeRange(1, 10_000);
    property("seed-replay", &g, 64, |&t| {
        let mut a = vec![0f32; 256];
        let mut b = vec![0f32; 256];
        conmezo::optimizer::sample_direction(&mut a, 250, 0xFEED, t);
        conmezo::optimizer::sample_direction(&mut b, 250, 0xFEED, t);
        a == b && a[250..].iter().all(|&v| v == 0.0)
    });
}

#[test]
fn prop_batcher_never_drops_or_duplicates_loss_mass() {
    let g = UsizeRange(1, 8);
    property("batch-loss-mass", &g, 32, |&n| {
        let gen = TaskGen::new(spec("trec").unwrap(), 256, 32);
        let data = gen.dataset(n, n as u64);
        let refs: Vec<&conmezo::data::Example> = data.iter().collect();
        let b = conmezo::data::finetune_batch(&refs, 8, 32);
        // exactly one unit of loss mass per example, none for pad rows
        (b.mask.iter().sum::<f32>() as usize) == n
    });
}

#[test]
fn prop_native_sample_u_is_a_pure_function_of_seed() {
    // the program-level seed-replay primitive behind fused distributed runs
    let rt = runtime();
    let prog = rt.load_kind("nano", "sample_u").unwrap();
    let g = UsizeRange(0, 50_000);
    property("sample-u-replay", &g, 16, |&s| {
        let a = lit_vec_f32(&prog.call(&[Arg::I32(s as i32)]).unwrap()[0]).unwrap();
        let b = lit_vec_f32(&prog.call(&[Arg::I32(s as i32)]).unwrap()[0]).unwrap();
        a == b
    });
}

// ---------------------------------------------------------------------------
// PJRT-only: first-order baselines as cross-backend checks (the native
// twins of these tests run unconditionally above)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_only {
    use super::*;

    fn pjrt_runtime() -> Option<Runtime> {
        match Runtime::from_name("pjrt") {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping pjrt-only test (no artifacts): {e}");
                None
            }
        }
    }

    #[test]
    fn fo_adamw_solves_task() {
        let Some(rt) = pjrt_runtime() else { return };
        let mut cfg = quick_cfg("adamw", 200);
        cfg.eta = 1e-3;
        cfg.eval_every = 100;
        let summary = Trainer::new(&rt, cfg).unwrap().run().unwrap();
        assert!(summary.final_accuracy > 0.9, "adamw acc {}", summary.final_accuracy);
    }

    #[test]
    fn evaluator_accuracy_on_oracle_params() {
        let Some(rt) = pjrt_runtime() else { return };
        let mut cfg = quick_cfg("adamw", 250);
        cfg.eta = 1e-3;
        let mut tr = Trainer::new(&rt, cfg).unwrap();
        for t in 0..250 {
            tr.step(t).unwrap();
        }
        let r = tr.evaluate().unwrap();
        assert!(r.accuracy() > 0.9, "{}", r.accuracy());
        assert!(r.macro_f1 > 0.85, "{}", r.macro_f1);
    }
}
