"""Fig. 3 synthetic quadratic: closed-form checks + AD cross-validation.

These same values are golden-tested on the Rust side
(rust/src/objective/quadratic.rs) so both implementations of the App. C.1
objective are pinned to each other through this file.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import quadratic
from compile.configs import QUAD_DIM


def test_sigma_endpoints_and_monotone():
    s = np.asarray(quadratic.sigmas())
    assert s.shape == (QUAD_DIM,)
    np.testing.assert_allclose(s[0], 1.0 / QUAD_DIM, rtol=1e-6)
    np.testing.assert_allclose(s[-1], 1.0, rtol=2e-4)
    assert np.all(np.diff(s) > 0)


def test_condition_number_is_d():
    s = np.asarray(quadratic.sigmas())
    np.testing.assert_allclose(s[-1] / s[0], QUAD_DIM, rtol=1e-4)


def test_loss_at_unit_vectors():
    s = np.asarray(quadratic.sigmas())
    for i in [0, 17, QUAD_DIM - 1]:
        x = jnp.zeros(QUAD_DIM).at[i].set(2.0)
        np.testing.assert_allclose(float(quadratic.quad_loss(x)[0]), 4.0 * s[i], rtol=1e-5)


def test_grad_matches_autodiff():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(QUAD_DIM), jnp.float32)
    got = quadratic.quad_grad(x)[0]
    want = jax.grad(lambda v: quadratic.quad_loss(v)[0])(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_minimum_at_origin():
    assert float(quadratic.quad_loss(jnp.zeros(QUAD_DIM))[0]) == 0.0
    x = jnp.full((QUAD_DIM,), 0.1)
    assert float(quadratic.quad_loss(x)[0]) > 0.0


def test_golden_value_for_rust_crosscheck():
    """x_i = 1 for all i: f = sum(sigmas). Pinned so Rust can assert the
    same constant (see rust objective::quadratic tests)."""
    x = jnp.ones(QUAD_DIM)
    total = float(quadratic.quad_loss(x)[0])
    # geometric series sum: (1/d) * (r^d - 1)/(r - 1), r = d^(1/(d-1))
    d = QUAD_DIM
    r = d ** (1.0 / (d - 1))
    want = (1.0 / d) * (r**d - 1.0) / (r - 1.0)
    np.testing.assert_allclose(total, want, rtol=1e-4)
