"""Step-program semantics: each fused HLO step must implement Algorithm 1
(and the baselines) exactly, verified against straight-line jnp references
that do not share code with the Pallas path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, steps
from compile.kernels import ref

CFG = configs.get("nano")


def batch(seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32)
    mask = jnp.zeros((CFG.batch, CFG.seq_len), jnp.float32).at[:, -1].set(1.0)
    return ids, tgt, mask


def init_state(seed=0):
    params = model.init_flat(CFG, jax.random.PRNGKey(seed))
    m = steps._sample_u(CFG, jnp.int32(seed + 1))
    return params, m


THETA, BETA, ETA, LAM = 1.35, 0.9, 1e-3, 1e-3


def conmezo_reference(params, m, seed, ids, tgt, mask):
    """Straight-line Algorithm 1 with the jnp oracle ops only."""
    cfg = dataclasses.replace(CFG, use_pallas=False)
    u = steps._sample_u(CFG, seed)
    z = ref.cone_direction_ref(m, u, jnp.float32(THETA), model.d_raw(CFG))
    lp = model.loss(cfg, params + LAM * z, ids, tgt, mask)
    lm = model.loss(cfg, params - LAM * z, ids, tgt, mask)
    g = (lp - lm) / (2 * LAM)
    xn, mn = ref.zo_update_ref(params, m, z, g, ETA, BETA)
    return xn, mn, lp, lm, g


def test_conmezo_step_matches_reference():
    params, m = init_state()
    ids, tgt, mask = batch()
    seed = jnp.int32(42)
    got = steps.conmezo_step(
        CFG, params, m, seed,
        jnp.float32(THETA), jnp.float32(BETA), jnp.float32(ETA), jnp.float32(LAM),
        ids, tgt, mask,
    )
    want = conmezo_reference(params, m, seed, ids, tgt, mask)
    for g_, w_, name in zip(got, want, ["params", "m", "lp", "lm", "g"]):
        np.testing.assert_allclose(
            np.asarray(g_), np.asarray(w_), rtol=1e-3, atol=1e-4, err_msg=name
        )


def test_conmezo_step_momentum_pads_stay_zero():
    params, m = init_state()
    ids, tgt, mask = batch()
    xn, mn, *_ = steps.conmezo_step(
        CFG, params, m, jnp.int32(7),
        jnp.float32(THETA), jnp.float32(BETA), jnp.float32(ETA), jnp.float32(LAM),
        ids, tgt, mask,
    )
    r = model.d_raw(CFG)
    assert np.all(np.asarray(mn[r:]) == 0.0)
    assert np.all(np.asarray(xn[r:]) == np.asarray(params[r:]))


def test_conmezo_step_seed_replay_deterministic():
    params, m = init_state()
    ids, tgt, mask = batch()
    args = (jnp.float32(THETA), jnp.float32(BETA), jnp.float32(ETA), jnp.float32(LAM), ids, tgt, mask)
    a = steps.conmezo_step(CFG, params, m, jnp.int32(9), *args)
    b = steps.conmezo_step(CFG, params, m, jnp.int32(9), *args)
    c = steps.conmezo_step(CFG, params, m, jnp.int32(10), *args)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    assert not np.array_equal(np.asarray(a[0]), np.asarray(c[0]))


def test_mezo_step_matches_two_point_identity():
    """x' must equal x - eta*g*z with g from the returned losses."""
    params, _ = init_state()
    ids, tgt, mask = batch()
    seed = jnp.int32(5)
    xn, lp, lm, g = steps.mezo_step(
        CFG, params, seed, jnp.float32(ETA), jnp.float32(LAM), ids, tgt, mask
    )
    z = steps._sample_u(CFG, seed)
    np.testing.assert_allclose(float(g), (float(lp) - float(lm)) / (2 * LAM), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(xn), np.asarray(params - ETA * g * z), rtol=1e-5, atol=1e-7
    )


def test_mezo_momentum_step_uses_momentum_as_update():
    params, m = init_state()
    ids, tgt, mask = batch()
    seed = jnp.int32(11)
    xn, mn, lp, lm, g = steps.mezo_momentum_step(
        CFG, params, m, seed, jnp.float32(BETA), jnp.float32(ETA), jnp.float32(LAM),
        ids, tgt, mask,
    )
    z = steps._sample_u(CFG, seed)
    m_want = BETA * m + (1 - BETA) * g * z
    np.testing.assert_allclose(np.asarray(mn), np.asarray(m_want), rtol=1e-4, atol=2e-6)
    np.testing.assert_allclose(
        np.asarray(xn), np.asarray(params - ETA * m_want), rtol=1e-5, atol=1e-7
    )


def test_two_point_consistent_with_loss():
    params, _ = init_state()
    ids, tgt, mask = batch()
    z = steps._sample_u(CFG, jnp.int32(3))
    lp, lm = steps.two_point(CFG, params, z, jnp.float32(LAM), ids, tgt, mask)
    cfg = dataclasses.replace(CFG, use_pallas=False)
    np.testing.assert_allclose(
        float(lp), float(model.loss(cfg, params + LAM * z, ids, tgt, mask)), rtol=5e-5
    )
    np.testing.assert_allclose(
        float(lm), float(model.loss(cfg, params - LAM * z, ids, tgt, mask)), rtol=5e-5
    )


def test_sample_u_moments():
    u = steps._sample_u(CFG, jnp.int32(0))
    r = model.d_raw(CFG)
    body = np.asarray(u[:r])
    assert abs(body.mean()) < 0.05
    assert abs(body.std() - 1.0) < 0.05
    assert np.all(np.asarray(u[r:]) == 0.0)


def test_fo_sgd_step_descends():
    params, _ = init_state()
    ids, tgt, mask = batch()
    l0 = None
    for _ in range(3):
        params, l = steps.fo_sgd_step(CFG, params, jnp.float32(0.5), ids, tgt, mask)
        if l0 is None:
            l0 = float(l)
    _, l_final = steps.fo_sgd_step(CFG, params, jnp.float32(0.0), ids, tgt, mask)
    assert float(l_final) < l0


def test_fo_adamw_step_matches_manual_math():
    params, _ = init_state()
    ids, tgt, mask = batch()
    d = model.d_pad(CFG)
    mu = jnp.zeros(d)
    nu = jnp.zeros(d)
    cfg = dataclasses.replace(CFG, use_pallas=False)
    l, grad = jax.value_and_grad(lambda p: model.loss(cfg, p, ids, tgt, mask))(params)
    xn, mu_n, nu_n, l_got = steps.fo_adamw_step(
        CFG, params, mu, nu, jnp.float32(1.0), jnp.float32(1e-3), ids, tgt, mask
    )
    np.testing.assert_allclose(float(l_got), float(l), rtol=1e-5)
    mu_want = (1 - steps.ADAM_B1) * grad
    np.testing.assert_allclose(np.asarray(mu_n), np.asarray(mu_want), rtol=1e-5, atol=1e-8)
    mu_hat = mu_want / (1 - steps.ADAM_B1)
    nu_hat = (1 - steps.ADAM_B2) * jnp.square(grad) / (1 - steps.ADAM_B2)
    x_want = params - 1e-3 * mu_hat / (jnp.sqrt(nu_hat) + steps.ADAM_EPS)
    np.testing.assert_allclose(np.asarray(xn), np.asarray(x_want), rtol=1e-4, atol=1e-7)


def test_grad_cos2_bounds_and_self_alignment():
    params, _ = init_state()
    ids, tgt, mask = batch()
    cfg = dataclasses.replace(CFG, use_pallas=False)
    _, grad = jax.value_and_grad(lambda p: model.loss(cfg, p, ids, tgt, mask))(params)
    grad = model.mask_pad(cfg, grad)
    cos2, _ = steps.grad_cos2(CFG, params, grad, ids, tgt, mask)
    np.testing.assert_allclose(float(cos2), 1.0, rtol=1e-4)
    u = steps._sample_u(CFG, jnp.int32(123))
    cos2_rand, _ = steps.grad_cos2(CFG, params, u, ids, tgt, mask)
    assert 0.0 <= float(cos2_rand) < 0.05  # ~1/d in expectation


def test_init_params_program_matches_model_init():
    got = steps.init_params(CFG, jnp.int32(4))[0]
    want = model.init_flat(CFG, jax.random.PRNGKey(jnp.uint32(4)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
