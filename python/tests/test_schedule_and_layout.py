"""Cross-language pins: the Rust BetaSchedule and the exported preset
layouts must agree with the python-side definitions (the paper's §3.4
formula and the manifest contract)."""

import math

import pytest

from compile import configs, model


def beta_warmup(t, beta_final=0.99, total=20_000):
    """Reference implementation of the §3.4 schedule (mirrors
    rust/src/optimizer/schedule.rs — keep in sync)."""
    s = total / 20_000.0
    t1, t2, w = 200 * s, 2000 * s, 1800 * s
    if t <= t1:
        return 0.1
    if t <= t2:
        r = (t - t1) / w
        return beta_final - (beta_final - 0.1) / (1 + 8 * r**1.8) ** 3
    return beta_final


def test_warmup_paper_breakpoints():
    assert beta_warmup(0) == 0.1
    assert beta_warmup(200) == 0.1
    # at the end of the ramp the deviation from beta_final is (bf-0.1)/9^3
    assert abs(beta_warmup(2000) - (0.99 - 0.89 / 729)) < 1e-9
    assert beta_warmup(2001) == 0.99


def test_warmup_monotone():
    prev = 0.0
    for t in range(0, 20_000, 50):
        b = beta_warmup(t)
        assert b >= prev - 1e-12
        prev = b


def test_warmup_10k_halves_intervals():
    assert beta_warmup(100, total=10_000) == 0.1
    assert beta_warmup(150, total=10_000) > 0.1
    assert beta_warmup(1001, total=10_000) == 0.99


@pytest.mark.parametrize("preset", ["nano", "tiny", "small", "medium"])
def test_every_preset_layout_is_contiguous(preset):
    cfg = configs.get(preset)
    off = 0
    for name, shape, o in model.layout(cfg):
        assert o == off, name
        off += math.prod(shape)
    assert off == model.d_raw(cfg)
    assert model.d_pad(cfg) % model.PAD_QUANTUM == 0


@pytest.mark.parametrize("preset", ["nano", "tiny", "small", "medium"])
def test_param_counts_are_ordered(preset):
    # the preset ladder must be strictly increasing in d
    order = ["nano", "tiny", "small", "medium", "xl"]
    cfg = configs.get(preset)
    nxt = order[order.index(preset) + 1]
    assert model.d_raw(cfg) < model.d_raw(configs.get(nxt))


def test_vocab_large_enough_for_task_layout():
    # rust/src/data/vocab.rs requires CONTENT_START + 16 < vocab
    for name in ["nano", "tiny", "small", "medium", "xl"]:
        assert configs.get(name).vocab > 12 + 16
