"""AOT pipeline integrity: export a preset to a temp dir and validate the
manifest/program contract the Rust runtime depends on."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    r = subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out-dir", str(out),
            "--presets", "nano",
            "--progs", "init,loss,conmezo_step,mezo_step,two_point,eval_logits,sample_u",
        ],
        cwd=PYDIR,
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    return out


def test_manifest_exists_and_valid(exported):
    with open(exported / "manifest.json") as f:
        man = json.load(f)
    assert man["version"] == 1
    assert "nano" in man["presets"]
    names = {p["name"] for p in man["programs"]}
    assert {"nano_init", "nano_loss", "nano_conmezo_step", "quad_loss"} <= names


def test_manifest_shapes_consistent(exported):
    with open(exported / "manifest.json") as f:
        man = json.load(f)
    preset = man["presets"]["nano"]
    dp = preset["d_pad"]
    for prog in man["programs"]:
        if prog["preset"] != "nano":
            continue
        for inp in prog["inputs"]:
            if inp["name"] in ("params", "m", "z", "u", "mu", "nu"):
                assert inp["shape"] == [dp], prog["name"]


def test_layout_covers_d_raw(exported):
    with open(exported / "manifest.json") as f:
        man = json.load(f)
    preset = man["presets"]["nano"]
    total = 0
    for ent in preset["layout"]:
        n = 1
        for sdim in ent["shape"]:
            n *= sdim
        assert ent["offset"] == total
        total += n
    assert total == preset["d_raw"]


def test_hlo_files_exist_and_parseable_header(exported):
    with open(exported / "manifest.json") as f:
        man = json.load(f)
    for prog in man["programs"]:
        path = exported / prog["file"]
        assert path.exists(), prog["name"]
        head = path.read_text()[:200]
        assert "HloModule" in head, prog["name"]


def test_programs_have_unique_names(exported):
    with open(exported / "manifest.json") as f:
        man = json.load(f)
    names = [p["name"] for p in man["programs"]]
    assert len(names) == len(set(names))
