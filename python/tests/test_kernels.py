"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Randomized shape/parameter sweeps (fixed seeds, hypothesis-style) — the
core build-time correctness signal for the exported artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention as attn_k
from compile.kernels import layernorm as ln_k
from compile.kernels import ref, zo_update as zk

RNG = np.random.default_rng(0)


def rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# cone_direction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d_pad,d_raw", [(1024, 1000), (2048, 2048), (4096, 3000), (8192, 7777)])
@pytest.mark.parametrize("theta", [0.0, 0.7, 1.35, np.pi / 2])
def test_cone_direction_matches_ref(d_pad, d_raw, theta):
    m = rand(d_pad) * (jnp.arange(d_pad) < d_raw)
    u = rand(d_pad)
    got = zk.cone_direction(m, u, jnp.float32(theta), d_raw)
    want = ref.cone_direction_ref(m, u, jnp.float32(theta), d_raw)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cone_direction_zeroes_padding():
    d_pad, d_raw = 2048, 1500
    m = rand(d_pad) * (jnp.arange(d_pad) < d_raw)
    u = rand(d_pad)  # noise in the pad region must not leak
    z = zk.cone_direction(m, u, jnp.float32(1.2), d_raw)
    assert np.all(np.asarray(z[d_raw:]) == 0.0)


def test_cone_direction_norm_identity():
    """E||z||^2 = d: with exact-unit u the norm identity is exact."""
    d = 4096
    m = rand(d)
    u_raw = rand(d)
    # project u to the sphere sqrt(d)*S^{d-1} so ||z||^2 == d exactly
    u = u_raw / jnp.linalg.norm(u_raw) * jnp.sqrt(jnp.float32(d))
    # and make u orthogonal to m to isolate the parallel/orthogonal split
    u = u - (jnp.vdot(u, m) / jnp.vdot(m, m)) * m
    u = u / jnp.linalg.norm(u) * jnp.sqrt(jnp.float32(d))
    z = zk.cone_direction(m, u, jnp.float32(0.9), d)
    # ||z||^2 = d cos^2 + sin^2 ||u||^2 = d cos^2 + d sin^2 = d
    np.testing.assert_allclose(float(jnp.vdot(z, z)), d, rtol=1e-4)


def test_cone_theta_zero_is_pure_momentum():
    d = 1024
    m, u = rand(d), rand(d)
    z = zk.cone_direction(m, u, jnp.float32(0.0), d)
    mhat = m / jnp.linalg.norm(m)
    np.testing.assert_allclose(z, jnp.sqrt(jnp.float32(d)) * mhat, rtol=1e-4, atol=1e-5)


def test_cone_theta_half_pi_is_pure_noise():
    d = 1024
    m, u = rand(d), rand(d)
    z = zk.cone_direction(m, u, jnp.float32(np.pi / 2), d)
    np.testing.assert_allclose(z, u, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("tile", [256, 1024, 4096])
def test_cone_direction_tile_invariance(tile):
    d = 8192
    m, u = rand(d), rand(d)
    a = zk.cone_direction(m, u, jnp.float32(1.1), d, tile=tile)
    b = ref.cone_direction_ref(m, u, jnp.float32(1.1), d)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# perturb / zo_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d", [1024, 5120, 65536])
@pytest.mark.parametrize("scale", [1e-3, -1e-3, 2.5])
def test_perturb_matches_ref(d, scale):
    x, z = rand(d), rand(d)
    got = zk.perturb(x, z, jnp.float32(scale))
    np.testing.assert_allclose(got, ref.perturb_ref(x, z, scale), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("d", [1024, 3072, 131072])
@pytest.mark.parametrize("g,eta,beta", [(0.5, 1e-6, 0.99), (-2.0, 1e-3, 0.9), (0.0, 1e-2, 0.0)])
def test_zo_update_matches_ref(d, g, eta, beta):
    x, m, z = rand(d), rand(d), rand(d)
    xo, mo = zk.zo_update(x, m, z, jnp.float32(g), jnp.float32(eta), jnp.float32(beta))
    xr, mr = ref.zo_update_ref(x, m, z, g, eta, beta)
    np.testing.assert_allclose(xo, xr, rtol=1e-4, atol=5e-7)
    np.testing.assert_allclose(mo, mr, rtol=1e-4, atol=5e-7)


def test_zo_update_beta_one_freezes_momentum():
    d = 1024
    x, m, z = rand(d), rand(d), rand(d)
    _, mo = zk.zo_update(x, m, z, jnp.float32(3.0), jnp.float32(1e-3), jnp.float32(1.0))
    np.testing.assert_allclose(mo, m, rtol=1e-6)


def test_zo_update_is_single_pass_equivalent():
    """Fused output must equal the two separate passes exactly (same order)."""
    d = 2048
    x, m, z = rand(d), rand(d), rand(d)
    g, eta, beta = 1.7, 1e-4, 0.95
    xo, mo = zk.zo_update(x, m, z, jnp.float32(g), jnp.float32(eta), jnp.float32(beta))
    np.testing.assert_allclose(xo, x - eta * g * z, rtol=1e-4, atol=5e-7)
    np.testing.assert_allclose(mo, beta * m + (1 - beta) * g * z, rtol=1e-4, atol=5e-7)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d", [(8, 32), (64, 64), (33, 128), (256, 256)])
def test_layernorm_matches_ref(n, d):
    x, g, b = rand(n, d), rand(d), rand(d)
    got = ln_k.layernorm(x, g, b)
    np.testing.assert_allclose(got, ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5)


def test_layernorm_output_standardized():
    x = rand(16, 64) * 10 + 3
    y = ln_k.layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.var(np.asarray(y), -1), 1.0, atol=1e-3)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,s,dh", [(1, 1, 16, 8), (2, 4, 32, 16), (2, 2, 64, 32), (1, 8, 128, 16)])
def test_attention_matches_ref(b, h, s, dh):
    q, k, v = rand(b, h, s, dh), rand(b, h, s, dh), rand(b, h, s, dh)
    got = attn_k.attention(q, k, v, causal=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("q_block", [4, 8, 32])
def test_attention_qblock_invariance(q_block):
    q, k, v = rand(1, 2, 32, 16), rand(1, 2, 32, 16), rand(1, 2, 32, 16)
    got = attn_k.attention(q, k, v, q_block=q_block)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_attention_causality():
    """Output at position t must not depend on tokens after t."""
    b, h, s, dh = 1, 2, 16, 8
    q, k, v = rand(b, h, s, dh), rand(b, h, s, dh), rand(b, h, s, dh)
    base = attn_k.attention(q, k, v)
    k2 = k.at[:, :, -1].set(99.0)
    v2 = v.at[:, :, -1].set(-99.0)
    pert = attn_k.attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(base[:, :, -1], pert[:, :, -1])


def test_attention_uniform_values():
    """With identical V rows, attention must return that row regardless of scores."""
    b, h, s, dh = 1, 1, 32, 8
    q, k = rand(b, h, s, dh), rand(b, h, s, dh)
    row = rand(dh)
    v = jnp.broadcast_to(row, (b, h, s, dh))
    out = attn_k.attention(q, k, v)
    np.testing.assert_allclose(out, v, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# cross-entropy oracle self-checks
# ---------------------------------------------------------------------------


def test_xent_uniform_logits():
    logits = jnp.zeros((2, 4, 16))
    targets = jnp.zeros((2, 4), jnp.int32)
    mask = jnp.ones((2, 4))
    got = ref.softmax_xent_ref(logits, targets, mask)
    np.testing.assert_allclose(float(got), np.log(16.0), rtol=1e-6)


def test_xent_respects_mask():
    logits = rand(2, 4, 16)
    targets = jnp.zeros((2, 4), jnp.int32)
    mask = jnp.zeros((2, 4)).at[0, 1].set(1.0)
    got = ref.softmax_xent_ref(logits, targets, mask)
    lz = jax.nn.logsumexp(logits[0, 1])
    np.testing.assert_allclose(float(got), float(lz - logits[0, 1, 0]), rtol=1e-5)
