"""L2 model correctness: layout integrity, forward shapes, pallas-vs-jnp
equivalence (the proof that the Pallas kernels compose into the model
without changing its math)."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model

NANO = configs.get("nano")


def make_batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    ids = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)), jnp.int32)
    mask = jnp.zeros((cfg.batch, cfg.seq_len), jnp.float32).at[:, -1].set(1.0)
    return ids, tgt, mask


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preset", ["nano", "tiny", "small"])
def test_layout_contiguous_and_ordered(preset):
    cfg = configs.get(preset)
    lay = model.layout(cfg)
    off = 0
    for name, shape, o in lay:
        assert o == off, f"{name} offset {o} != expected {off}"
        off += math.prod(shape)
    assert off == model.d_raw(cfg)
    assert model.d_pad(cfg) % model.PAD_QUANTUM == 0
    assert model.d_pad(cfg) >= model.d_raw(cfg)


def test_layout_names_unique():
    lay = model.layout(NANO)
    names = [n for n, _, _ in lay]
    assert len(names) == len(set(names))


def test_unflatten_roundtrip():
    cfg = NANO
    flat = jnp.arange(model.d_pad(cfg), dtype=jnp.float32)
    p = model.unflatten(cfg, flat)
    for name, shape, off in model.layout(cfg):
        n = math.prod(shape)
        np.testing.assert_array_equal(
            np.asarray(p[name]).ravel(), np.arange(off, off + n, dtype=np.float32)
        )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def test_init_pads_zero_and_ln_gains_one():
    cfg = NANO
    flat = model.init_flat(cfg, jax.random.PRNGKey(0))
    assert flat.shape == (model.d_pad(cfg),)
    assert np.all(np.asarray(flat[model.d_raw(cfg):]) == 0.0)
    p = model.unflatten(cfg, flat)
    np.testing.assert_array_equal(np.asarray(p["ln_f.g"]), 1.0)
    np.testing.assert_array_equal(np.asarray(p["layer0.ln1.b"]), 0.0)


def test_init_deterministic_per_seed():
    cfg = NANO
    a = model.init_flat(cfg, jax.random.PRNGKey(7))
    b = model.init_flat(cfg, jax.random.PRNGKey(7))
    c = model.init_flat(cfg, jax.random.PRNGKey(8))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def test_forward_shapes_and_finite():
    cfg = NANO
    flat = model.init_flat(cfg, jax.random.PRNGKey(0))
    ids, tgt, mask = make_batch(cfg)
    logits = model.forward(cfg, flat, ids)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))
    l = model.loss(cfg, flat, ids, tgt, mask)
    assert np.isfinite(float(l))


def test_fresh_model_loss_near_uniform():
    """A freshly initialized LM should score ~log(V) per token."""
    cfg = NANO
    flat = model.init_flat(cfg, jax.random.PRNGKey(0))
    ids, tgt, mask = make_batch(cfg)
    l = float(model.loss(cfg, flat, ids, tgt, mask))
    assert abs(l - np.log(cfg.vocab)) < 0.5


def test_pallas_and_jnp_forward_agree():
    """The L1 kernels must not change the model's math."""
    cfg = NANO
    cfg_ref = dataclasses.replace(cfg, use_pallas=False)
    flat = model.init_flat(cfg, jax.random.PRNGKey(1))
    ids, _, _ = make_batch(cfg, seed=3)
    a = model.forward(cfg, flat, ids)
    b = model.forward(cfg_ref, flat, ids)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_padding_lanes_do_not_affect_loss():
    cfg = NANO
    flat = model.init_flat(cfg, jax.random.PRNGKey(0))
    ids, tgt, mask = make_batch(cfg)
    base = float(model.loss(cfg, flat, ids, tgt, mask))
    poisoned = flat.at[model.d_raw(cfg):].set(123.0)
    got = float(model.loss(cfg, poisoned, ids, tgt, mask))
    assert base == got


def test_causal_lm_ignores_future_tokens():
    cfg = NANO
    flat = model.init_flat(cfg, jax.random.PRNGKey(0))
    ids, _, _ = make_batch(cfg)
    logits = model.forward(cfg, flat, ids)
    ids2 = ids.at[:, -1].set((ids[:, -1] + 1) % cfg.vocab)
    logits2 = model.forward(cfg, flat, ids2)
    np.testing.assert_allclose(
        np.asarray(logits[:, :-1]), np.asarray(logits2[:, :-1]), rtol=1e-4, atol=1e-5
    )


def test_eval_logits_matches_forward_gather():
    cfg = NANO
    flat = model.init_flat(cfg, jax.random.PRNGKey(0))
    ids, _, _ = make_batch(cfg)
    pos = jnp.asarray([3, 7, 1, 15], jnp.int32)
    got = model.eval_logits(cfg, flat, ids, pos)
    logits = model.forward(cfg, flat, ids)
    want = jnp.stack([logits[i, int(pos[i])] for i in range(cfg.batch)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)
