"""Build-time compile path: JAX/Pallas model + AOT lowering to HLO text.

Nothing in this package is imported at runtime; `make artifacts` runs it
once and the Rust coordinator consumes only `artifacts/*.hlo.txt` +
`artifacts/manifest.json` afterwards.
"""
