"""Synthetic strongly-convex quadratic of Fig. 3 / App. C.1.

f(x) = sum_i sigma_i x_i^2 with (sigma_i) a geometric series from 1/d to 1,
so the condition number is d. The Rust side also implements this objective
natively (`objective::NativeQuadratic`) for the 10^5-step grid sweeps; the
HLO export here is used by integration tests to prove the composed-mode
path end to end and to cross-check the native implementation bit-for-bit
at f32 tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

from .configs import QUAD_DIM


def sigmas(d: int = QUAD_DIM):
    """Geometric series 1/d -> 1 inclusive (App. C.1)."""
    i = jnp.arange(d, dtype=jnp.float32)
    ratio = jnp.asarray(float(d), jnp.float32) ** (1.0 / (d - 1))
    return (1.0 / d) * ratio**i


def quad_loss(x):
    """f(x); x: f32 [QUAD_DIM]."""
    return (jnp.sum(sigmas(x.shape[0]) * jnp.square(x)),)


def quad_grad(x):
    """Analytic gradient 2*sigma*x (used by tests only)."""
    return (2.0 * sigmas(x.shape[0]) * x,)
