"""AOT lowering: JAX/Pallas programs -> artifacts/*.hlo.txt + manifest.json.

HLO *text* (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 (the version the published `xla` 0.1.6 crate links) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Run once via `make artifacts`; the Rust binary is self-contained after.

Usage:
    python -m compile.aot --out-dir ../artifacts --presets nano,tiny,small
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, quadratic, steps

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _inputs_for(cfg, kind):
    """(name, ShapeDtypeStruct) list per program kind."""
    dp = model.d_pad(cfg)
    b, s = cfg.batch, cfg.seq_len
    vec = spec([dp])
    scalar = spec([])
    iscalar = spec([], I32)
    batch = [
        ("input_ids", spec([b, s], I32)),
        ("targets", spec([b, s], I32)),
        ("mask", spec([b, s])),
    ]
    table = {
        "init": [("seed", iscalar)],
        "loss_pallas": [("params", vec)] + batch,
        "sample_u": [("seed", iscalar)],
        "loss": [("params", vec)] + batch,
        "eval_logits": [("params", vec), ("input_ids", spec([b, s], I32)), ("pos", spec([b], I32))],
        "two_point": [("params", vec), ("z", vec), ("lam", scalar)] + batch,
        "conmezo_step": [
            ("params", vec), ("m", vec), ("seed", iscalar),
            ("theta", scalar), ("beta", scalar), ("eta", scalar), ("lam", scalar),
        ] + batch,
        "mezo_step": [("params", vec), ("seed", iscalar), ("eta", scalar), ("lam", scalar)] + batch,
        "mezo_momentum_step": [
            ("params", vec), ("m", vec), ("seed", iscalar),
            ("beta", scalar), ("eta", scalar), ("lam", scalar),
        ] + batch,
        "fo_sgd_step": [("params", vec), ("eta", scalar)] + batch,
        "fo_adamw_step": [
            ("params", vec), ("mu", vec), ("nu", vec), ("t", scalar), ("eta", scalar),
        ] + batch,
        "grad_cos2": [("params", vec), ("m", vec)] + batch,
    }
    return table[kind]


_OUTPUTS = {
    "init": ["params"],
    "loss_pallas": ["loss"],
    "sample_u": ["u"],
    "loss": ["loss"],
    "eval_logits": ["logits"],
    "two_point": ["loss_plus", "loss_minus"],
    "conmezo_step": ["params", "m", "loss_plus", "loss_minus", "proj_grad"],
    "mezo_step": ["params", "loss_plus", "loss_minus", "proj_grad"],
    "mezo_momentum_step": ["params", "m", "loss_plus", "loss_minus", "proj_grad"],
    "fo_sgd_step": ["params", "loss"],
    "fo_adamw_step": ["params", "mu", "nu", "loss"],
    "grad_cos2": ["cos2", "loss"],
}

def loss_pallas(cfg, params, input_ids, targets, mask):
    """Ablation variant: model forward with the Pallas attention/LN kernels."""
    import dataclasses

    c = dataclasses.replace(cfg, use_pallas=True)
    return (model.loss(c, params, input_ids, targets, mask),)


_FNS = {
    "init": steps.init_params,
    "loss_pallas": loss_pallas,
    "sample_u": steps.sample_u,
    "loss": steps.loss_only,
    "eval_logits": steps.eval_logits,
    "two_point": steps.two_point,
    "conmezo_step": steps.conmezo_step,
    "mezo_step": steps.mezo_step,
    "mezo_momentum_step": steps.mezo_momentum_step,
    "fo_sgd_step": steps.fo_sgd_step,
    "fo_adamw_step": steps.fo_adamw_step,
    "grad_cos2": steps.grad_cos2,
}

DEFAULT_PROGS = list(_FNS)


def export_program(cfg, kind, out_dir):
    ins = _inputs_for(cfg, kind)
    fn = _FNS[kind]

    def wrapped(*args):
        out = fn(cfg, *args)
        return out if isinstance(out, tuple) else tuple(out)

    t0 = time.time()
    lowered = jax.jit(wrapped).lower(*[s for _, s in ins])
    text = to_hlo_text(lowered)
    name = f"{cfg.name}_{kind}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    entry = {
        "name": name,
        "preset": cfg.name,
        "kind": kind,
        "file": os.path.basename(path),
        "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        "inputs": [
            {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)} for n, s in ins
        ],
        "outputs": _OUTPUTS[kind],
        "lower_seconds": round(time.time() - t0, 2),
    }
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {entry['lower_seconds']}s", flush=True)
    return entry


def export_quadratic(out_dir):
    entries = []
    for kind, fn in [("loss", quadratic.quad_loss), ("grad", quadratic.quad_grad)]:
        lowered = jax.jit(fn).lower(spec([configs.QUAD_DIM]))
        text = to_hlo_text(lowered)
        name = f"quad_{kind}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "preset": "quad",
                "kind": kind,
                "file": f"{name}.hlo.txt",
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "inputs": [{"name": "x", "dtype": "float32", "shape": [configs.QUAD_DIM]}],
                "outputs": ["loss" if kind == "loss" else "grad"],
            }
        )
        print(f"  {name}: ok", flush=True)
    return entries


def preset_meta(cfg):
    return {
        "name": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "d_ff": cfg.d_ff,
        "d_raw": model.d_raw(cfg),
        "d_pad": model.d_pad(cfg),
        "layout": [
            {"name": n, "shape": list(s), "offset": o} for n, s, o in model.layout(cfg)
        ],
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="nano,tiny,small,medium")
    ap.add_argument("--progs", default=",".join(DEFAULT_PROGS))
    ap.add_argument("--skip-quad", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    progs = args.progs.split(",")
    unknown = set(progs) - set(DEFAULT_PROGS)
    if unknown:
        sys.exit(f"unknown programs: {sorted(unknown)}")

    manifest = {"version": 1, "programs": [], "presets": {}}
    if not args.skip_quad:
        print("quadratic:")
        manifest["programs"] += export_quadratic(args.out_dir)
    for pname in args.presets.split(","):
        cfg = configs.get(pname)
        print(f"preset {pname} (d_raw={model.d_raw(cfg)}, d_pad={model.d_pad(cfg)}):")
        manifest["presets"][pname] = preset_meta(cfg)
        for kind in progs:
            manifest["programs"].append(export_program(cfg, kind, args.out_dir))

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['programs'])} programs + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
