"""L2 fused step programs — the units the Rust coordinator executes.

Each function here is a pure JAX function of (state, scalars, batch) that
aot.py lowers to one HLO program. Two families:

*  Fused ZO steps (`conmezo_step`, `mezo_step`, `mezo_momentum_step`): the
   entire optimizer iteration — seeded direction sampling, cone
   construction (Pallas), both forward passes, and the fused
   parameter+momentum update (Pallas) — is a single XLA program. Python is
   never on the step path, and the Rust side only moves O(1) scalars per
   step once the state buffers live on device.

*  Composed-mode helpers (`loss`, `two_point`, `eval_logits`) used by the
   exotic baselines (HiZOO / LOZO / MeZO-SVRG / ZO-AdaMM) whose extra
   per-coordinate state lives host-side in Rust `vecmath`.

First-order programs (`fo_sgd_step`, `fo_adamw_step`, `grad_cos2`) exist
for the paper's FO baselines (Tables 1 & 9, Fig. 4) and for Fig. 6's
momentum/true-gradient alignment probe; they use the pure-jnp forward
(backprop through interpret-mode Pallas is exercised separately in tests but
kept off the exported FO path for compile-time economy).

Hyperparameters (theta, beta, eta, lambda) are *runtime scalar inputs*, not
baked constants — the beta warm-up schedule (§3.4) is driven per step from
Rust without recompilation.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import model
from .configs import ModelConfig
from .kernels import zo_update as zk


def _key(seed):
    return jax.random.PRNGKey(seed.astype(jnp.uint32))


def _sample_u(cfg: ModelConfig, seed):
    """Standard-normal direction over the padded buffer, pads zeroed.

    This is the seed-replay primitive: the same int32 seed always yields the
    same direction, so distributed workers regenerate z locally from a
    broadcast seed instead of receiving d floats (DESIGN.md §4).
    """
    u = jax.random.normal(_key(seed), (model.d_pad(cfg),), jnp.float32)
    return model.mask_pad(cfg, u)


# ---------------------------------------------------------------------------
# ZO fused steps
# ---------------------------------------------------------------------------


def conmezo_step(cfg: ModelConfig, params, m, seed, theta, beta, eta, lam, input_ids, targets, mask):
    """Algorithm 1, one iteration, fully fused.

    Returns (params', m', loss_plus, loss_minus, proj_grad).
    """
    d = model.d_raw(cfg)
    u = _sample_u(cfg, seed)
    z = zk.cone_direction(m, u, theta, d)
    xp = zk.perturb(params, z, lam)
    lp = model.loss(cfg, xp, input_ids, targets, mask)
    xm = zk.perturb(params, z, -lam)
    lm = model.loss(cfg, xm, input_ids, targets, mask)
    g = (lp - lm) / (2.0 * lam)
    x_new, m_new = zk.zo_update(params, m, z, g, eta, beta)
    return x_new, m_new, lp, lm, g


def mezo_step(cfg: ModelConfig, params, seed, eta, lam, input_ids, targets, mask):
    """MeZO (Malladi et al. 2023): isotropic two-point SPSA step.

    Returns (params', loss_plus, loss_minus, proj_grad).
    """
    z = _sample_u(cfg, seed)
    xp = zk.perturb(params, z, lam)
    lp = model.loss(cfg, xp, input_ids, targets, mask)
    xm = zk.perturb(params, z, -lam)
    lm = model.loss(cfg, xm, input_ids, targets, mask)
    g = (lp - lm) / (2.0 * lam)
    x_new = zk.perturb(params, z, -eta * g)
    return x_new, lp, lm, g


def mezo_momentum_step(cfg: ModelConfig, params, m, seed, beta, eta, lam, input_ids, targets, mask):
    """The paper's MeZO+Momentum baseline (§5.2): momentum *replaces* the
    update direction but does not bias the perturbation.

    m' = beta*m + (1-beta)*g*z ;  x' = x - eta*m'.
    Returns (params', m', loss_plus, loss_minus, proj_grad).
    """
    z = _sample_u(cfg, seed)
    xp = zk.perturb(params, z, lam)
    lp = model.loss(cfg, xp, input_ids, targets, mask)
    xm = zk.perturb(params, z, -lam)
    lm = model.loss(cfg, xm, input_ids, targets, mask)
    g = (lp - lm) / (2.0 * lam)
    # reuse the fused kernel with eta=0 to get m', then apply x' = x - eta*m'
    _, m_new = zk.zo_update(params, m, z, g, 0.0, beta)
    x_new = zk.perturb(params, m_new, -eta)
    return x_new, m_new, lp, lm, g


# ---------------------------------------------------------------------------
# Composed-mode helpers
# ---------------------------------------------------------------------------


def loss_only(cfg: ModelConfig, params, input_ids, targets, mask):
    return (model.loss(cfg, params, input_ids, targets, mask),)


def two_point(cfg: ModelConfig, params, z, lam, input_ids, targets, mask):
    """f(x + lam*z), f(x - lam*z) for a host-provided direction z."""
    xp = zk.perturb(params, z, lam)
    lp = model.loss(cfg, xp, input_ids, targets, mask)
    xm = zk.perturb(params, z, -lam)
    lm = model.loss(cfg, xm, input_ids, targets, mask)
    return lp, lm


def eval_logits(cfg: ModelConfig, params, input_ids, pos):
    return (model.eval_logits(cfg, params, input_ids, pos),)


def sample_u(cfg: ModelConfig, seed):
    return (_sample_u(cfg, seed),)


def init_params(cfg: ModelConfig, seed):
    return (model.init_flat(cfg, _key(seed)),)


# ---------------------------------------------------------------------------
# First-order programs (build-time backprop; baselines + probes)
# ---------------------------------------------------------------------------


def _fo_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, use_pallas=False)


def fo_sgd_step(cfg: ModelConfig, params, eta, input_ids, targets, mask):
    c = _fo_cfg(cfg)
    l, grad = jax.value_and_grad(lambda p: model.loss(c, p, input_ids, targets, mask))(params)
    return params - eta * grad, l


ADAM_B1, ADAM_B2, ADAM_EPS, ADAM_WD = 0.9, 0.999, 1e-8, 0.0


def fo_adamw_step(cfg: ModelConfig, params, mu, nu, t, eta, input_ids, targets, mask):
    """AdamW with bias correction; t is the 1-based step counter (f32)."""
    c = _fo_cfg(cfg)
    l, grad = jax.value_and_grad(lambda p: model.loss(c, p, input_ids, targets, mask))(params)
    mu_n = ADAM_B1 * mu + (1.0 - ADAM_B1) * grad
    nu_n = ADAM_B2 * nu + (1.0 - ADAM_B2) * jnp.square(grad)
    mu_hat = mu_n / (1.0 - ADAM_B1**t)
    nu_hat = nu_n / (1.0 - ADAM_B2**t)
    step = mu_hat / (jnp.sqrt(nu_hat) + ADAM_EPS) + ADAM_WD * params
    return params - eta * step, mu_n, nu_n, l


def grad_cos2(cfg: ModelConfig, params, m, input_ids, targets, mask):
    """cos^2 between momentum and the true gradient (Fig. 6 probe)."""
    c = _fo_cfg(cfg)
    l, grad = jax.value_and_grad(lambda p: model.loss(c, p, input_ids, targets, mask))(params)
    grad = model.mask_pad(c, grad)
    num = jnp.square(jnp.vdot(m, grad))
    den = jnp.maximum(jnp.vdot(m, m) * jnp.vdot(grad, grad), 1e-30)
    return num / den, l
