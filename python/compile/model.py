"""L2: decoder-only transformer LM over a single flat parameter buffer.

The flat buffer is the paper's central implementation object (§3.3): all
perturbation and update math happens on one contiguous f32 vector, never on
a per-tensor pytree. This module defines:

  * the parameter layout (name, shape, offset) and the padded flat dim,
  * `forward` / `loss` / `eval_logits` that unflatten views on the fly,
  * `init_flat` returning a freshly initialized flat buffer.

The forward path calls the L1 Pallas kernels (attention, layernorm) so that
they lower into the same HLO program the Rust runtime executes; a pure-jnp
variant (cfg.use_pallas=False) exists for first-order/grad programs and for
the kernel-vs-ref speed comparison.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import attention as attn_k
from .kernels import layernorm as ln_k
from .kernels import ref as kref

PAD_QUANTUM = 1024


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------


def layout(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...], int]]:
    """Ordered (name, shape, offset) for every parameter tensor."""
    entries: List[Tuple[str, Tuple[int, ...]]] = []
    d, ff, v, s = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    entries.append(("tok_emb", (v, d)))
    entries.append(("pos_emb", (s, d)))
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        entries += [
            (p + "ln1.g", (d,)),
            (p + "ln1.b", (d,)),
            (p + "attn.wqkv", (d, 3 * d)),
            (p + "attn.bqkv", (3 * d,)),
            (p + "attn.wo", (d, d)),
            (p + "attn.bo", (d,)),
            (p + "ln2.g", (d,)),
            (p + "ln2.b", (d,)),
            (p + "mlp.w1", (d, ff)),
            (p + "mlp.b1", (ff,)),
            (p + "mlp.w2", (ff, d)),
            (p + "mlp.b2", (d,)),
        ]
    entries += [("ln_f.g", (d,)), ("ln_f.b", (d,))]
    out, off = [], 0
    for name, shape in entries:
        out.append((name, shape, off))
        off += math.prod(shape)
    return out


def d_raw(cfg: ModelConfig) -> int:
    lay = layout(cfg)
    name, shape, off = lay[-1]
    return off + math.prod(shape)


def d_pad(cfg: ModelConfig) -> int:
    r = d_raw(cfg)
    return ((r + PAD_QUANTUM - 1) // PAD_QUANTUM) * PAD_QUANTUM


def unflatten(cfg: ModelConfig, flat) -> Dict[str, jax.Array]:
    """Slice the flat buffer into named parameter views (no copies in XLA)."""
    params = {}
    for name, shape, off in layout(cfg):
        n = 1
        for sdim in shape:
            n *= sdim
        params[name] = flat[off : off + n].reshape(shape)
    return params


def mask_pad(cfg: ModelConfig, vec):
    """Zero the padding lanes of a padded flat vector."""
    valid = (jnp.arange(vec.shape[0]) < d_raw(cfg)).astype(vec.dtype)
    return vec * valid


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_flat(cfg: ModelConfig, key) -> jax.Array:
    """GPT-2-style init, written directly into the padded flat buffer."""
    chunks = []
    for name, shape, _ in layout(cfg):
        key, sub = jax.random.split(key)
        n = 1
        for sdim in shape:
            n *= sdim
        if name.endswith((".g",)):
            chunks.append(jnp.ones(n, jnp.float32))
        elif name.endswith((".b", ".bqkv", ".bo", ".b1", ".b2")):
            chunks.append(jnp.zeros(n, jnp.float32))
        elif name.endswith("wo") or name.endswith("w2"):
            # residual-branch projections scaled down by depth
            std = 0.02 / (2.0 * cfg.n_layers) ** 0.5
            chunks.append(std * jax.random.normal(sub, (n,), jnp.float32))
        else:
            chunks.append(0.02 * jax.random.normal(sub, (n,), jnp.float32))
    flat = jnp.concatenate(chunks)
    pad = d_pad(cfg) - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros(pad, jnp.float32)])


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _ln(cfg, x2d, g, b):
    if cfg.use_pallas:
        return ln_k.layernorm(x2d, g, b)
    return kref.layernorm_ref(x2d, g, b)


def _attention(cfg, q, k, v):
    if cfg.use_pallas:
        return attn_k.attention(q, k, v, causal=True)
    return kref.attention_ref(q, k, v, causal=True)


def forward(cfg: ModelConfig, flat, input_ids) -> jax.Array:
    """Token logits. input_ids: int32 [B, S] -> logits f32 [B, S, V]."""
    p = unflatten(cfg, flat)
    bsz, s = input_ids.shape
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim

    x = p["tok_emb"][input_ids] + p["pos_emb"][None, :s, :]
    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        # --- attention block (pre-LN) ---
        hx = _ln(cfg, x.reshape(bsz * s, d), p[pre + "ln1.g"], p[pre + "ln1.b"]).reshape(bsz, s, d)
        qkv = hx @ p[pre + "attn.wqkv"] + p[pre + "attn.bqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)
        k = k.reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)
        v = v.reshape(bsz, s, h, hd).transpose(0, 2, 1, 3)
        o = _attention(cfg, q, k, v)
        o = o.transpose(0, 2, 1, 3).reshape(bsz, s, d)
        x = x + o @ p[pre + "attn.wo"] + p[pre + "attn.bo"]
        # --- MLP block ---
        hx = _ln(cfg, x.reshape(bsz * s, d), p[pre + "ln2.g"], p[pre + "ln2.b"]).reshape(bsz, s, d)
        hx = jax.nn.gelu(hx @ p[pre + "mlp.w1"] + p[pre + "mlp.b1"])
        x = x + hx @ p[pre + "mlp.w2"] + p[pre + "mlp.b2"]

    x = _ln(cfg, x.reshape(bsz * s, d), p["ln_f.g"], p["ln_f.b"]).reshape(bsz, s, d)
    return x @ p["tok_emb"].T  # tied LM head


def loss(cfg: ModelConfig, flat, input_ids, targets, mask) -> jax.Array:
    """Masked mean cross-entropy; the ZO oracle f(x) of the paper."""
    logits = forward(cfg, flat, input_ids)
    return kref.softmax_xent_ref(logits, targets, mask)


def eval_logits(cfg: ModelConfig, flat, input_ids, pos) -> jax.Array:
    """Logits at one position per example (classification readout).

    pos: int32 [B] -> returns f32 [B, V]. The Rust evaluator restricts the
    argmax to the task's verbalizer tokens.
    """
    logits = forward(cfg, flat, input_ids)
    return jax.vmap(lambda l, q: l[q])(logits, pos)
