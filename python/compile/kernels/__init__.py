"""L1 Pallas kernels: the ZO flat-buffer hot path and the transformer
compute hot-spots, all lowered under interpret=True so the exported HLO runs
on any PJRT backend (see DESIGN.md)."""

from . import attention, layernorm, ref, zo_update  # noqa: F401
