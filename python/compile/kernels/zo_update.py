"""L1 Pallas kernels for the ZO flat-buffer hot path (paper Sec. 3.3, App. B).

The paper's implementation contribution is a *fused, vectorized* treatment of
the flattened parameter buffer: cone-direction construction, two-point
perturbation and the combined (parameter, momentum) update are each a single
streaming pass instead of per-parameter Python loops.

TPU mapping (DESIGN.md "Hardware adaptation"): the flat buffer is tiled into
1-D VMEM-resident blocks of `TILE` float32 lanes; each grid step streams one
block HBM->VMEM, applies the fused elementwise math on the VPU, and writes
back. Arithmetic intensity is O(1) flop/byte, so the roofline is HBM
bandwidth and the optimization goal is *minimal passes over the buffer* —
which is exactly what fusing the momentum update into the parameter update
achieves (3 passes/step vs MeZO-loop's 4; see EXPERIMENTS.md Table 3).

All kernels run under `interpret=True` (CPU PJRT cannot execute Mosaic
custom-calls); they lower to plain HLO loops and fuse into the surrounding
jitted program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: 2^16 f32 lanes = 256 KiB/block; with 4 live operands
# (x, m, z, out) that is ~1 MiB VMEM, far under the ~16 MiB/core budget,
# leaving room for double buffering.
TILE = 65536


def _grid(d_pad: int, tile: int) -> int:
    assert d_pad % tile == 0, f"padded dim {d_pad} must be a multiple of {tile}"
    return d_pad // tile


def pick_tile(d_pad: int, target: int | None = None) -> int:
    """Block size for the flat-buffer schedule.

    Under interpret=True each grid cell costs ~2.5 ms of buffer-copy
    overhead on the CPU PJRT backend (measured in EXPERIMENTS.md §Perf), so
    the exported CPU programs use a SINGLE block (grid=1) — the fused
    elementwise pass is then one XLA loop at memory bandwidth. On a real
    TPU the VMEM-sized tiling is what you want: pass ``target=TILE`` to get
    the largest power-of-two tile <= target dividing d_pad. Tests exercise
    both schedules against the same oracle.
    """
    if target is None:
        return d_pad
    t = target
    while t > 1 and d_pad % t != 0:
        t //= 2
    return t


# ---------------------------------------------------------------------------
# cone_direction: z = sqrt(d_raw) * cos(theta)/||m|| * m + sin(theta) * u
# ---------------------------------------------------------------------------


def _cone_kernel(cs_ref, sn_ref, m_ref, u_ref, z_ref, *, tile, d_raw):
    i = pl.program_id(0)
    idx = i * tile + jax.lax.broadcasted_iota(jnp.int32, (tile,), 0)
    valid = (idx < d_raw).astype(jnp.float32)
    z_ref[...] = (cs_ref[0] * m_ref[...] + sn_ref[0] * u_ref[...]) * valid


def cone_direction(m, u, theta, d_raw, tile=None):
    """Pallas cone-direction construction over the padded flat buffer.

    The scalar prefactors (which need a global reduction ||m||) are computed
    by XLA outside the kernel; the kernel performs the bandwidth-bound fused
    scale-add with pad masking.
    """
    d_pad = m.shape[0]
    tile = tile or pick_tile(d_pad)
    d = jnp.asarray(d_raw, jnp.float32)
    mnorm = jnp.maximum(jnp.linalg.norm(m), 1e-30)
    cs = (jnp.sqrt(d) * jnp.cos(theta) / mnorm).reshape(1)
    sn = jnp.sin(theta).reshape(1).astype(jnp.float32)
    kern = functools.partial(_cone_kernel, tile=tile, d_raw=d_raw)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        grid=(_grid(d_pad, tile),),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # cs broadcast
            pl.BlockSpec((1,), lambda i: (0,)),  # sn broadcast
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(cs, sn, m, u)


# ---------------------------------------------------------------------------
# perturb: x + scale * z  (used for the +lambda and -2*lambda hops)
# ---------------------------------------------------------------------------


def _axpy_kernel(s_ref, x_ref, z_ref, o_ref):
    o_ref[...] = x_ref[...] + s_ref[0] * z_ref[...]


def perturb(x, z, scale, tile=None):
    """x + scale * z in one streaming pass (MeZO's efficient_perturb)."""
    d_pad = x.shape[0]
    tile = tile or pick_tile(d_pad)
    s = jnp.asarray(scale, jnp.float32).reshape(1)
    return pl.pallas_call(
        _axpy_kernel,
        out_shape=jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        grid=(_grid(d_pad, tile),),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        interpret=True,
    )(s, x, z)


# ---------------------------------------------------------------------------
# zo_update: fused x' = x - eta*g*z ; m' = beta*m + (1-beta)*g*z
# ---------------------------------------------------------------------------


def _zo_update_kernel(c_ref, x_ref, m_ref, z_ref, xo_ref, mo_ref):
    # c = [eta*g, beta, (1-beta)*g] precomputed scalars
    gz_eta = c_ref[0] * z_ref[...]
    xo_ref[...] = x_ref[...] - gz_eta
    mo_ref[...] = c_ref[1] * m_ref[...] + c_ref[2] * z_ref[...]


def zo_update(x, m, z, g, eta, beta, tile=None):
    """The paper's fused parameter+momentum update: one pass, two outputs.

    This is the single most important fusion: it halves the buffer traffic
    of the update phase relative to running the two updates separately.
    """
    d_pad = x.shape[0]
    tile = tile or pick_tile(d_pad)
    g = jnp.asarray(g, jnp.float32)
    c = jnp.stack(
        [
            jnp.asarray(eta, jnp.float32) * g,
            jnp.asarray(beta, jnp.float32),
            (1.0 - jnp.asarray(beta, jnp.float32)) * g,
        ]
    )
    xo, mo = pl.pallas_call(
        _zo_update_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d_pad,), jnp.float32),
            jax.ShapeDtypeStruct((d_pad,), jnp.float32),
        ),
        grid=(_grid(d_pad, tile),),
        in_specs=[
            pl.BlockSpec((3,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=(
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ),
        interpret=True,
    )(c, x, m, z)
    return xo, mo
