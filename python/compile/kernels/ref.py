"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: each Pallas kernel in this package
must match its oracle to float32 tolerance across randomized shape sweeps
(see python/tests/test_kernels.py). They are also used directly by the
"no-pallas" model variant exported for speed comparisons.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# ZO flat-buffer kernels (the paper's Section 3.3 fused operations)
# ---------------------------------------------------------------------------


def cone_direction_ref(m, u, theta, d_raw):
    """z = sqrt(d) * (cos(theta) * m/||m|| + sin(theta) * u), pad lanes zeroed.

    `m` and `u` have padded length d_pad >= d_raw; entries at index >= d_raw
    are structurally zero in `m` and must be zeroed in `z` so padding never
    perturbs, contributes to norms, or leaks into momentum.

    Following App. C.2/C.3 of the paper, `u` is standard normal rather than
    uniform on the sphere (E||u||^2 = d), so the sqrt(d) factor multiplies
    only the momentum component; the noise component is scaled by sin(theta)
    alone, exactly as in the paper's reference implementation (App. B).
    """
    d = jnp.asarray(d_raw, jnp.float32)
    valid = (jnp.arange(m.shape[0]) < d_raw).astype(m.dtype)
    mnorm = jnp.maximum(jnp.linalg.norm(m), 1e-30)
    cs = jnp.sqrt(d) * jnp.cos(theta) / mnorm
    sn = jnp.sin(theta)
    return (cs * m + sn * u) * valid


def perturb_ref(x, z, scale):
    """x + scale * z (the MeZO/ConMeZO two-point perturbation)."""
    return x + scale * z


def zo_update_ref(x, m, z, g, eta, beta):
    """Fused ConMeZO parameter + momentum update.

    x' = x - eta * g * z
    m' = beta * m + (1 - beta) * g * z

    Returns (x', m'). A single pass over the flat buffer; the Pallas kernel
    fuses both writes (the paper's "fused in-place operations").
    """
    gz = g * z
    return x - eta * gz, beta * m + (1.0 - beta) * gz


def dot_ref(a, b):
    """<a, b> over the flat buffer (used for projected-gradient checks)."""
    return jnp.sum(a * b)


# ---------------------------------------------------------------------------
# Transformer kernels
# ---------------------------------------------------------------------------


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def attention_ref(q, k, v, causal=True):
    """Multi-head scaled-dot-product attention.

    q, k, v: [B, H, S, Dh]. Returns [B, H, S, Dh].
    """
    s = q.shape[-2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def softmax_xent_ref(logits, targets, mask):
    """Masked mean token cross-entropy.

    logits: [B, S, V]; targets: int32 [B, S]; mask: float32 [B, S].
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
