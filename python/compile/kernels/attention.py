"""L1 Pallas causal multi-head attention kernel.

GPU->TPU rethink (DESIGN.md "Hardware adaptation"): the CUDA flash-attention
formulation assigns a threadblock per (batch, head, q-tile) and streams K/V
tiles through shared memory. On TPU the analogue is a Pallas grid over
(batch*head, q-tile) with `BlockSpec` expressing the HBM->VMEM schedule:
each grid step holds one Q tile plus the full K/V panel for that head in
VMEM (S * Dh * 4 B each — 32 KiB at S=512, Dh=64, comfortably resident),
computes the masked scores on the MXU, and keeps the softmax row statistics
in registers so probabilities are never re-read from HBM.

For the sequence lengths this repo trains at (S <= 256) the full-panel
schedule is strictly better than a streamed K/V loop: it avoids the online
rescaling FLOPs and the K/V panel already fits VMEM. The streamed variant
would only pay off at S >~ 8K (VMEM budget 16 MiB / (2 * Dh * 4B) lanes).

Runs under interpret=True; lowers to plain HLO so the CPU PJRT client can
execute the exported program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Q_BLOCK = 32


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, q_block, causal, scale):
    iq = pl.program_id(1)
    q = q_ref[0]  # [q_block, Dh]
    k = k_ref[0]  # [S, Dh]
    v = v_ref[0]  # [S, Dh]
    scores = jnp.dot(q, k.T) * scale  # [q_block, S]
    if causal:
        s = k.shape[0]
        qi = iq * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, s), 0)
        ki = jax.lax.broadcasted_iota(jnp.int32, (q_block, s), 1)
        scores = jnp.where(qi >= ki, scores, -1e30)
    # numerically-stable softmax with row stats kept local
    mx = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - mx)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v)


def attention(q, k, v, causal=True, q_block=None):
    """Tiled causal attention. q, k, v: [B, H, S, Dh] -> [B, H, S, Dh]."""
    b, h, s, dh = q.shape
    qb = q_block or Q_BLOCK
    while s % qb != 0 and qb > 1:
        qb //= 2
    scale = 1.0 / float(dh) ** 0.5
    # collapse batch and head into one grid axis: [B*H, S, Dh]
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    kern = functools.partial(_attn_kernel, q_block=qb, causal=causal, scale=scale)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), jnp.float32),
        grid=(b * h, s // qb),
        in_specs=[
            pl.BlockSpec((1, qb, dh), lambda ib, iq: (ib, iq, 0)),
            pl.BlockSpec((1, s, dh), lambda ib, iq: (ib, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda ib, iq: (ib, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, dh), lambda ib, iq: (ib, iq, 0)),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)
