"""L1 Pallas fused LayerNorm kernel.

Rows of the [N, D] activation matrix are normalized independently; the grid
iterates over row blocks so the row statistics (mean, variance) stay in
VMEM/registers and the normalize+scale+shift happens in the same pass as the
reduction — one read and one write of the activation per row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 8


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]  # [rows, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


def layernorm(x, gamma, beta, eps=1e-5, row_block=None):
    """Fused LayerNorm over the last axis of a 2-D [N, D] input.

    Higher-rank inputs are flattened to rows by the caller (model.py).
    """
    n, d = x.shape
    rb = row_block or ROW_BLOCK
    while n % rb != 0 and rb > 1:
        rb //= 2
    kern = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        grid=(n // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        interpret=True,
    )(x, gamma.reshape(1, d), beta.reshape(1, d))
