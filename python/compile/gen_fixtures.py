"""Generate golden-value fixtures for the Rust NativeBackend parity tests.

Writes two fixtures under ``rust/tests/fixtures/``:

* ``native_parity.json`` — expected loss / two-point / eval-logits values
  for the nano preset, computed with a numpy transcription of the native
  backend's math and cross-checked here against the jax reference
  (`model.py` + `kernels/ref.py`) before being written — so the fixture
  pins the Rust implementation to the paper reference.

* ``fo_parity.json`` — first-order golden values for the native
  reverse-mode autograd pass (`rust/src/runtime/autograd.rs`):
  `jax.value_and_grad` loss + gradient norm + strided gradient samples,
  the Fig. 6 `grad_cos2` probe, the SGD displacement norm and a two-step
  AdamW trajectory (all via `compile.steps`' fo programs).

The parameter buffer is not stored; it is regenerated from the seed by a
bit-exact mirror of the Rust init PRNG (xoshiro256++ / splitmix64 /
polar-method Gaussians), and guarded by sum/sumsq checksums.

Usage:
    python -m compile.gen_fixtures          # from python/
"""

from __future__ import annotations

import json
import math
import os

import numpy as np

M64 = (1 << 64) - 1
STREAM_DIRECTION = 0x444952454354
STREAM_INIT = 0x494E4954
PAD_QUANTUM = 1024


# --- bit-exact mirror of rust/src/util/rng.rs ------------------------------


def _splitmix64(state):
    state = (state + 0x9E3779B97F4A7C15) & M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
    return state, (z ^ (z >> 31))


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Xoshiro256pp:
    def __init__(self, seed):
        sm = seed & M64
        self.s = []
        for _ in range(4):
            sm, v = _splitmix64(sm)
            self.s.append(v)
        self.spare = None

    @classmethod
    def derive_stream(cls, seed, purpose, index):
        sm = (seed ^ _rotl(purpose, 24) ^ _rotl(index, 48)) & M64
        sm, a = _splitmix64(sm)
        _, b = _splitmix64((a ^ index) & M64)
        return cls(b)

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_normal(self):
        if self.spare is not None:
            v, self.spare = self.spare, None
            return v
        while True:
            a = 2.0 * self.next_f64() - 1.0
            b = 2.0 * self.next_f64() - 1.0
            r = a * a + b * b
            if 0.0 < r < 1.0:
                f = math.sqrt(-2.0 * math.log(r) / r)
                self.spare = b * f
                return a * f

    def fill_normal_f32(self, n):
        return np.array([self.next_normal() for _ in range(n)], dtype=np.float32)


# --- native init / sample_u mirrors (rust/src/runtime/model.rs) ------------


def _layout(cfg):
    import compile.model as model

    return model.layout(cfg)


def init_flat(cfg, seed):
    import compile.model as model

    out = np.zeros(model.d_pad(cfg), dtype=np.float32)
    for idx, (name, shape, off) in enumerate(_layout(cfg)):
        n = int(np.prod(shape))
        if name.endswith(".g"):
            out[off:off + n] = 1.0
        elif name.endswith((".b", "bqkv", ".bo", ".b1", ".b2")):
            pass
        else:
            if name.endswith((".wo", ".w2")):
                std = np.float32(0.02 / math.sqrt(2.0 * cfg.n_layers))
            else:
                std = np.float32(0.02)
            rng = Xoshiro256pp.derive_stream(seed & 0xFFFFFFFF, STREAM_INIT, idx)
            out[off:off + n] = rng.fill_normal_f32(n) * std
    return out


def sample_u(cfg, seed):
    import compile.model as model

    u = np.zeros(model.d_pad(cfg), dtype=np.float32)
    rng = Xoshiro256pp.derive_stream(seed & 0xFFFFFFFF, STREAM_DIRECTION, 0)
    u[: model.d_raw(cfg)] = rng.fill_normal_f32(model.d_raw(cfg))
    return u


def gen_fo_parity(cfg, flat, m_buf, init_seed, m_seed, ids, tgt, msk, out_dir):
    """First-order golden values: jax.value_and_grad over the reference
    model, plus the fo_sgd / fo_adamw / grad_cos2 step programs."""
    import jax
    import jax.numpy as jnp

    import compile.model as model
    import compile.steps as steps

    b, s = cfg.batch, cfg.seq_len
    jids, jtgt, jmsk = jnp.asarray(ids), jnp.asarray(tgt), jnp.asarray(msk)
    loss, grad = jax.value_and_grad(
        lambda p: model.loss(cfg, p, jids, jtgt, jmsk)
    )(jnp.asarray(flat))
    grad = np.asarray(model.mask_pad(cfg, grad), dtype=np.float64)
    d_raw = model.d_raw(cfg)
    assert np.all(grad[d_raw:] == 0.0)

    stride = 997
    samples = [float(grad[i]) for i in range(0, d_raw, stride)]

    cos2, probe_loss = steps.grad_cos2(cfg, jnp.asarray(flat), jnp.asarray(m_buf), jids, jtgt, jmsk)
    assert abs(float(probe_loss) - float(loss)) < 1e-5 * max(abs(float(loss)), 1.0)

    sgd_eta, adamw_eta = 0.1, 1e-3
    x_sgd, _ = steps.fo_sgd_step(cfg, jnp.asarray(flat), jnp.float32(sgd_eta), jids, jtgt, jmsk)
    sgd_disp = np.asarray(x_sgd, np.float64) - flat.astype(np.float64)

    x = jnp.asarray(flat)
    mu = jnp.zeros_like(x)
    nu = jnp.zeros_like(x)
    adamw_loss2 = None
    for t in (1.0, 2.0):
        x, mu, nu, l = steps.fo_adamw_step(
            cfg, x, mu, nu, jnp.float32(t), jnp.float32(adamw_eta), jids, jtgt, jmsk
        )
        adamw_loss2 = float(l)
    adamw_disp = np.asarray(x, np.float64) - flat.astype(np.float64)

    fixture = {
        "preset": cfg.name,
        "batch": b,
        "seq": s,
        "init_seed": init_seed,
        "m_seed": m_seed,
        "input_ids": np.asarray(ids).flatten().tolist(),
        "targets": np.asarray(tgt).flatten().tolist(),
        "mask": np.asarray(msk).flatten().tolist(),
        "sgd_eta": sgd_eta,
        "adamw_eta": adamw_eta,
        "grad_sample_stride": stride,
        "expected": {
            "loss": float(loss),
            "grad_l2": float(np.linalg.norm(grad)),
            "grad_samples": samples,
            "grad_cos2": float(cos2),
            "sgd_disp_l2": float(np.linalg.norm(sgd_disp)),
            "adamw_loss2": adamw_loss2,
            "adamw_disp_l2": float(np.linalg.norm(adamw_disp)),
        },
        "tolerance": 1e-3,
    }
    path = os.path.join(out_dir, "fo_parity.json")
    with open(path, "w") as f:
        json.dump(fixture, f, indent=1)
    print(
        f"wrote {path}: loss={float(loss):.6f} |grad|={float(np.linalg.norm(grad)):.6f} "
        f"cos2={float(cos2):.3e}"
    )


def main():
    import jax.numpy as jnp

    import compile.configs as configs
    import compile.model as model

    cfg = configs.get("nano")
    b, s, v = cfg.batch, cfg.seq_len, cfg.vocab

    init_seed, z_seed, lam = 5, 9, 1e-3
    flat = init_flat(cfg, init_seed)
    z = sample_u(cfg, z_seed)

    # deterministic token batch (no task-generator dependency)
    ids = np.array([[(i * 7 + t * 3) % v for t in range(s)] for i in range(b)], np.int32)
    tgt = np.array([[(i * 5 + t * 11) % v for t in range(s)] for i in range(b)], np.int32)
    msk = np.zeros((b, s), np.float32)
    for i in range(b):
        msk[i, (3 * i + 2) % s] = 1.0

    jf, jids = jnp.asarray(flat), jnp.asarray(ids)
    loss = float(model.loss(cfg, jf, jids, jnp.asarray(tgt), jnp.asarray(msk)))
    lp = float(model.loss(cfg, jnp.asarray(flat + np.float32(lam) * z), jids, jnp.asarray(tgt), jnp.asarray(msk)))
    lm = float(model.loss(cfg, jnp.asarray(flat - np.float32(lam) * z), jids, jnp.asarray(tgt), jnp.asarray(msk)))
    pos = np.array([s - 1] * b, np.int32)
    ev = np.asarray(model.eval_logits(cfg, jf, jids, jnp.asarray(pos)))

    fixture = {
        "preset": "nano",
        "batch": b,
        "seq": s,
        "init_seed": init_seed,
        "z_seed": z_seed,
        "lam": lam,
        "input_ids": ids.flatten().tolist(),
        "targets": tgt.flatten().tolist(),
        "mask": msk.flatten().tolist(),
        "eval_pos": pos.tolist(),
        "expected": {
            "loss": loss,
            "loss_plus": lp,
            "loss_minus": lm,
            "eval_logits_row0": [float(x) for x in ev[0]],
            "params_sum": float(flat.astype(np.float64).sum()),
            "params_sumsq": float((flat.astype(np.float64) ** 2).sum()),
            "u_sum": float(z.astype(np.float64).sum()),
            "u_sumsq": float((z.astype(np.float64) ** 2).sum()),
        },
        "tolerance": 1e-4,
    }
    out = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "native_parity.json")
    with open(path, "w") as f:
        json.dump(fixture, f, indent=1)
    print(f"wrote {path}: loss={loss:.6f} lp={lp:.6f} lm={lm:.6f}")

    # the first-order fixture reuses the same deterministic batch and the
    # same mirrored init/direction buffers (m = sample_u(cfg, z_seed))
    gen_fo_parity(cfg, flat, z, init_seed, z_seed, ids, tgt, msk, out)


if __name__ == "__main__":
    main()
