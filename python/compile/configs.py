"""Model size presets.

The paper finetunes RoBERTa-large (355M), OPT-1.3B and OPT-13B on a single
H100. This repo runs on one CPU core (repro band 0/5 -> simulate the
hardware gate, DESIGN.md §2), so each paper model is mapped to a preset that
preserves the *regime* (d >> task difficulty, identical code path) at a
budget the testbed can train in minutes:

  tiny   ~0.2M params  <- RoBERTa-large stand-in (6-task GLUE-sim suite)
  small  ~1.3M params  <- OPT-1.3B stand-in      (8-task suite)
  medium ~6.5M params  <- OPT-13B stand-in
  xl     ~45M  params  <- large-model e2e option (examples/e2e, documented)
  nano   ~30K  params  <- unit/integration-test fixture

Every preset is exported by aot.py with the same program set, so the Rust
coordinator is model-size agnostic.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer configuration (pre-LN, learned positions,
    tied embeddings)."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int
    d_ff: int = 0  # 0 -> 4*d_model
    # Model-internal kernels (attention/LayerNorm). The Pallas variants are
    # exported as `{preset}_loss_pallas` for the kernel ablation bench; the
    # default step programs use the XLA-fused jnp path because interpret-mode
    # Pallas attention is ~30x slower on the CPU PJRT testbed (measured in
    # EXPERIMENTS.md §Perf). The paper's L1 contribution — the ZO flat-buffer
    # kernels in kernels/zo_update.py — is ALWAYS Pallas in every step
    # program regardless of this flag.
    use_pallas: bool = False

    def __post_init__(self):
        if self.d_ff == 0:
            object.__setattr__(self, "d_ff", 4 * self.d_model)
        assert self.d_model % self.n_heads == 0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


PRESETS = {
    "nano": ModelConfig("nano", vocab=64, d_model=32, n_layers=2, n_heads=2, seq_len=16, batch=4),
    "tiny": ModelConfig("tiny", vocab=256, d_model=64, n_layers=3, n_heads=4, seq_len=32, batch=8),
    "small": ModelConfig("small", vocab=512, d_model=128, n_layers=6, n_heads=8, seq_len=64, batch=8),
    "medium": ModelConfig("medium", vocab=512, d_model=256, n_layers=8, n_heads=8, seq_len=64, batch=8),
    "xl": ModelConfig("xl", vocab=4096, d_model=512, n_layers=12, n_heads=8, seq_len=128, batch=8),
}

# Synthetic quadratic of Fig. 3 / App. C.1: d = 1000, condition number d.
QUAD_DIM = 1000


def get(name: str) -> ModelConfig:
    try:
        return PRESETS[name]
    except KeyError:
        raise KeyError(f"unknown preset {name!r}; choose from {sorted(PRESETS)}")
