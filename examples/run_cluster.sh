#!/usr/bin/env bash
# Fault-injection smoke run for the multi-process ZO cluster.
#
# Launches 1 leader + 3 workers as real OS processes over localhost TCP.
# Worker 2 checkpoints periodically and crashes mid-run (--die-at-step);
# the leader drops it, renormalizes the step average over the survivors,
# and keeps training. The worker is then relaunched from its checkpoint
# and rejoins via seed replay (the leader ships the missed (seed, g,
# theta, eta, beta) records — O(1) bytes per missed step). The leader's
# divergence tripwire re-verifies parameter hashes right after the rejoin
# and periodically thereafter.
#
# PASS iff the run completes AND all three workers print the same final
# params_hash (bit-identical replicas despite the crash), AND the leader
# observed at least one rejoin.
#
#   examples/run_cluster.sh            # build if needed, then run
#   STEPS=300 DIE_AT=80 examples/run_cluster.sh
set -euo pipefail
cd "$(dirname "$0")/.."

ADDR="${ADDR:-127.0.0.1:7391}"
STEPS="${STEPS:-150}"
PRESET="${PRESET:-nano}"
DIE_AT="${DIE_AT:-40}"
WORK="$(mktemp -d)"
trap 'kill $(jobs -p) 2>/dev/null || true; rm -rf "$WORK"' EXIT
# leader step trace (one JSONL record per step); point TRACE_OUT outside
# $WORK to keep it after the cleanup trap (CI uploads it as an artifact)
TRACE_OUT="${TRACE_OUT:-$WORK/leader_trace.jsonl}"

BIN="${BIN:-rust/target/release/conmezo}"
if [ ! -x "$BIN" ]; then
    cargo build --release --manifest-path rust/Cargo.toml
fi

common=(--preset "$PRESET" --steps "$STEPS" --seed 42 --eta 3e-4 --lam 1e-3 --eval-every 0)

"$BIN" leader --listen "$ADDR" --workers 3 "${common[@]}" \
    --proj-timeout-ms 2000 --max-strikes 2 --hash-check-every 25 \
    --metrics-every 25 --trace "$TRACE_OUT" \
    --step-log "$WORK/steps.cmzl" >"$WORK/leader.log" 2>&1 &
LEADER=$!

"$BIN" worker --connect "$ADDR" --worker-id 0 "${common[@]}" >"$WORK/w0.log" 2>&1 &
"$BIN" worker --connect "$ADDR" --worker-id 1 "${common[@]}" >"$WORK/w1.log" 2>&1 &

# worker 2: checkpoint every 10 steps, injected crash at step $DIE_AT
# (runs in the foreground so the relaunch happens right after it dies)
if "$BIN" worker --connect "$ADDR" --worker-id 2 "${common[@]}" \
    --ckpt "$WORK/w2.ckpt" --ckpt-every 10 --die-at-step "$DIE_AT" \
    >"$WORK/w2_crash.log" 2>&1; then
    echo "FAIL: worker 2 was supposed to crash at step $DIE_AT" >&2
    exit 1
fi
echo "worker 2 crashed at step $DIE_AT; relaunching from its checkpoint"

"$BIN" worker --connect "$ADDR" --worker-id 2 "${common[@]}" \
    --init-from "$WORK/w2.ckpt" --ckpt "$WORK/w2.ckpt" >"$WORK/w2.log" 2>&1 &

fail() {
    echo "FAIL: $1" >&2
    echo "--- leader.log ---" >&2; cat "$WORK/leader.log" >&2 || true
    for w in w0 w1 w2_crash w2; do
        echo "--- $w.log ---" >&2; cat "$WORK/$w.log" >&2 || true
    done
    exit 1
}

wait "$LEADER" || fail "leader exited nonzero"
wait || fail "a worker exited nonzero"

# bit-identity: every worker's final parameter hash must match
h0=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/w0.log" | tail -1 || true)
h1=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/w1.log" | tail -1 || true)
h2=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/w2.log" | tail -1 || true)
[ -n "$h0" ] || fail "worker 0 reported no final hash"
[ "$h0" = "$h1" ] || fail "worker 1 diverged: $h1 != $h0"
[ "$h0" = "$h2" ] || fail "rejoined worker 2 diverged: $h2 != $h0"

# and the leader must have actually exercised the recovery path
grep -q 'rejoins' "$WORK/leader.log" || fail "leader saw no rejoin"
[ -s "$WORK/steps.cmzl" ] || fail "step log was not persisted"

# telemetry: the health line fired and the step trace holds one JSONL
# record per step (parseable by `conmezo trace-summary`)
grep -q 'health t=' "$WORK/leader.log" || fail "leader printed no health line"
[ -s "$TRACE_OUT" ] || fail "leader step trace was not written"
tl=$(wc -l <"$TRACE_OUT")
[ "$tl" -eq "$STEPS" ] || fail "trace has $tl records, expected $STEPS"
"$BIN" trace-summary "$TRACE_OUT" >/dev/null || fail "trace-summary rejected the trace"

echo "PASS: crash at step $DIE_AT, rejoin via seed replay, 3 replicas bit-identical ($h0)"
