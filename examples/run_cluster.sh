#!/usr/bin/env bash
# Fault-injection smoke run for the multi-process ZO cluster.
#
# Scenario 1 — worker crash + rejoin. 1 leader + 3 workers as real OS
# processes over localhost TCP. Worker 2 checkpoints periodically and
# crashes mid-run (--die-at-step); the leader drops it, renormalizes the
# step average over the survivors, and keeps training. The worker is then
# relaunched from its checkpoint and rejoins via seed replay (the leader
# ships the missed (seed, g, theta, eta, beta) records — O(1) bytes per
# missed step). The leader's divergence tripwire re-verifies parameter
# hashes right after the rejoin and periodically thereafter.
#
# Scenario 2 — leader crash + WAL resume. A second run persists the step
# WAL with --fsync every-step; once the WAL holds $KILL_RECORDS durable
# steps the leader is SIGKILLed mid-run and relaunched with --resume. The
# workers (started with --reconnect) ride out the outage, re-admit via
# seed replay, and the run must finish with all three params_hash lines
# bit-identical to an uninterrupted baseline of the same run.
#
# PASS iff both scenarios complete with bit-identical replicas.
#
#   examples/run_cluster.sh            # build if needed, then run
#   STEPS=300 DIE_AT=80 examples/run_cluster.sh
set -euo pipefail
cd "$(dirname "$0")/.."

ADDR="${ADDR:-127.0.0.1:7391}"
STEPS="${STEPS:-150}"
PRESET="${PRESET:-nano}"
DIE_AT="${DIE_AT:-40}"
WORK="$(mktemp -d)"
trap 'kill $(jobs -p) 2>/dev/null || true; rm -rf "$WORK"' EXIT
# leader step trace (one JSONL record per step); point TRACE_OUT outside
# $WORK to keep it after the cleanup trap (CI uploads it as an artifact)
TRACE_OUT="${TRACE_OUT:-$WORK/leader_trace.jsonl}"
# scenario-2 artifacts (WAL + resumed-leader logs); point OUT_DIR outside
# $WORK to keep them after the cleanup trap
OUT_DIR="${OUT_DIR:-$WORK}"
mkdir -p "$OUT_DIR"

BIN="${BIN:-rust/target/release/conmezo}"
if [ ! -x "$BIN" ]; then
    cargo build --release --manifest-path rust/Cargo.toml
fi

common=(--preset "$PRESET" --steps "$STEPS" --seed 42 --eta 3e-4 --lam 1e-3 --eval-every 0)

"$BIN" leader --listen "$ADDR" --workers 3 "${common[@]}" \
    --proj-timeout-ms 2000 --max-strikes 2 --hash-check-every 25 \
    --metrics-every 25 --trace "$TRACE_OUT" \
    --step-log "$WORK/steps.cmzw" >"$WORK/leader.log" 2>&1 &
LEADER=$!

"$BIN" worker --connect "$ADDR" --worker-id 0 "${common[@]}" >"$WORK/w0.log" 2>&1 &
"$BIN" worker --connect "$ADDR" --worker-id 1 "${common[@]}" >"$WORK/w1.log" 2>&1 &

# worker 2: checkpoint every 10 steps, injected crash at step $DIE_AT
# (runs in the foreground so the relaunch happens right after it dies)
if "$BIN" worker --connect "$ADDR" --worker-id 2 "${common[@]}" \
    --ckpt "$WORK/w2.ckpt" --ckpt-every 10 --die-at-step "$DIE_AT" \
    >"$WORK/w2_crash.log" 2>&1; then
    echo "FAIL: worker 2 was supposed to crash at step $DIE_AT" >&2
    exit 1
fi
echo "worker 2 crashed at step $DIE_AT; relaunching from its checkpoint"

"$BIN" worker --connect "$ADDR" --worker-id 2 "${common[@]}" \
    --init-from "$WORK/w2.ckpt" --ckpt "$WORK/w2.ckpt" >"$WORK/w2.log" 2>&1 &

fail() {
    echo "FAIL: $1" >&2
    for f in "$WORK"/*.log "$OUT_DIR"/*.log; do
        [ -f "$f" ] || continue
        echo "--- $(basename "$f") ---" >&2; cat "$f" >&2 || true
    done
    exit 1
}

wait "$LEADER" || fail "leader exited nonzero"
wait || fail "a worker exited nonzero"

# bit-identity: every worker's final parameter hash must match
h0=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/w0.log" | tail -1 || true)
h1=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/w1.log" | tail -1 || true)
h2=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/w2.log" | tail -1 || true)
[ -n "$h0" ] || fail "worker 0 reported no final hash"
[ "$h0" = "$h1" ] || fail "worker 1 diverged: $h1 != $h0"
[ "$h0" = "$h2" ] || fail "rejoined worker 2 diverged: $h2 != $h0"

# and the leader must have actually exercised the recovery path
grep -q 'rejoins' "$WORK/leader.log" || fail "leader saw no rejoin"
[ -s "$WORK/steps.cmzw" ] || fail "step log was not persisted"

# telemetry: the health line fired and the step trace holds one JSONL
# record per step (parseable by `conmezo trace-summary`)
grep -q 'health t=' "$WORK/leader.log" || fail "leader printed no health line"
[ -s "$TRACE_OUT" ] || fail "leader step trace was not written"
tl=$(wc -l <"$TRACE_OUT")
[ "$tl" -eq "$STEPS" ] || fail "trace has $tl records, expected $STEPS"
"$BIN" trace-summary "$TRACE_OUT" >/dev/null || fail "trace-summary rejected the trace"

echo "PASS: crash at step $DIE_AT, rejoin via seed replay, 3 replicas bit-identical ($h0)"

# ---------------------------------------------------------------------------
# Scenario 2: SIGKILL the LEADER mid-run, resume it from its WAL
# ---------------------------------------------------------------------------
STEPS2="${STEPS2:-100}"
KILL_RECORDS="${KILL_RECORDS:-30}"   # SIGKILL once this many steps are durable
WAL2="$OUT_DIR/leader_kill_steps.cmzw"
rm -f "$WAL2"
common2=(--preset "$PRESET" --steps "$STEPS2" --seed 43 --eta 3e-4 --lam 1e-3 --eval-every 0)
leader2=(--listen "$ADDR" --workers 3 --proj-timeout-ms 2000 --hash-check-every 25 --metrics-every 20)

# baseline: the identical run, uninterrupted
"$BIN" leader "${leader2[@]}" "${common2[@]}" >"$WORK/base_leader.log" 2>&1 &
BASE=$!
for i in 0 1 2; do
    "$BIN" worker --connect "$ADDR" --worker-id "$i" "${common2[@]}" >"$WORK/base_w$i.log" 2>&1 &
done
wait "$BASE" || fail "scenario-2 baseline leader exited nonzero"
wait || fail "a scenario-2 baseline worker exited nonzero"
hb=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/base_w0.log" | tail -1 || true)
[ -n "$hb" ] || fail "scenario-2 baseline reported no final hash"

# the run we interrupt: WAL persisted with every-step durability, workers
# armed to ride out the leader outage and reconnect
"$BIN" leader "${leader2[@]}" "${common2[@]}" \
    --step-log "$WAL2" --fsync every-step >"$OUT_DIR/kill_leader_first.log" 2>&1 &
LEADER2=$!
for i in 0 1 2; do
    "$BIN" worker --connect "$ADDR" --worker-id "$i" "${common2[@]}" \
        --reconnect 10 >"$WORK/kill_w$i.log" 2>&1 &
done

# wait for $KILL_RECORDS durable step cells (4 B magic + 33 B per cell;
# consensus cells only make the file larger), then SIGKILL — no clean
# shutdown, no flush: whatever the WAL holds is all the next leader gets
min_size=$((4 + 33 * KILL_RECORDS))
sz=0
for _ in $(seq 1 300); do
    sz=$(stat -c %s "$WAL2" 2>/dev/null || echo 0)
    [ "$sz" -ge "$min_size" ] && break
    kill -0 "$LEADER2" 2>/dev/null || fail "scenario-2 leader died before the kill point"
    sleep 0.1
done
[ "$sz" -ge "$min_size" ] || fail "WAL never reached $KILL_RECORDS records (size $sz)"
kill -9 "$LEADER2"
wait "$LEADER2" 2>/dev/null || true
echo "leader SIGKILLed with $sz B of WAL durable; resuming from it"

"$BIN" leader "${leader2[@]}" "${common2[@]}" \
    --step-log "$WAL2" --fsync every-step --resume >"$OUT_DIR/kill_leader_resumed.log" 2>&1 &
LEADER2B=$!
wait "$LEADER2B" || fail "resumed leader exited nonzero"
wait || fail "a worker exited nonzero after the leader restart"

grep -q 'resumed from WAL' "$OUT_DIR/kill_leader_resumed.log" || fail "resumed leader did not report WAL recovery"
k0=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/kill_w0.log" | tail -1 || true)
k1=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/kill_w1.log" | tail -1 || true)
k2=$(grep -o 'params_hash=[0-9a-f]*' "$WORK/kill_w2.log" | tail -1 || true)
[ -n "$k0" ] || fail "worker 0 reported no final hash after the leader restart"
{ [ "$k0" = "$k1" ] && [ "$k0" = "$k2" ]; } || fail "replicas diverged after the leader restart: $k0 $k1 $k2"
[ "$k0" = "$hb" ] || fail "leader restart changed the trajectory: $k0 != baseline $hb"

echo "PASS: leader SIGKILL + --resume, 3 replicas bit-identical to the uninterrupted run ($k0)"
