//! Finetune a suite of tasks with any optimizer from a TOML config, the way
//! a downstream user would drive the framework.
//!
//!   cargo run --release --example finetune_suite -- [config.toml] \
//!       [--set train.optimizer=hizoo] [--set train.steps=500]
//!
//! Without a config file it runs the built-in demo suite (three tasks,
//! ConMeZO vs MeZO) and prints a comparison table.

use conmezo::util::error::Result;
use conmezo::config::Config;
use conmezo::coordinator::{render_table, Mode, RunRecord, TrainConfig, Trainer};
use conmezo::runtime::Runtime;
use conmezo::util::json::Json;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg_file = Config::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--set" {
            cfg_file.set_from_str(&args[i + 1])?;
            i += 2;
        } else {
            cfg_file = Config::load(std::path::Path::new(&args[i]))?;
            i += 1;
        }
    }

    let rt = Runtime::open_default()?;
    let preset = cfg_file.str_or("model.preset", "nano");
    let steps = cfg_file.usize_or("train.steps", 3000);
    let eta = cfg_file.f64_or("train.eta", 3e-4) as f32;
    let tasks: Vec<String> = match cfg_file.get("train.tasks") {
        Some(conmezo::config::Value::Array(a)) => a
            .iter()
            .filter_map(|v| match v {
                conmezo::config::Value::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect(),
        _ => vec!["sst2".into(), "rte".into(), "trec".into()],
    };
    let optimizers: Vec<String> = match cfg_file.get("train.optimizers") {
        Some(conmezo::config::Value::Array(a)) => a
            .iter()
            .filter_map(|v| match v {
                conmezo::config::Value::Str(s) => Some(s.clone()),
                _ => None,
            })
            .collect(),
        _ => vec![cfg_file.str_or("train.optimizer", "conmezo"), "mezo".into()],
    };

    println!("suite: preset={preset} tasks={tasks:?} optimizers={optimizers:?} steps={steps}");
    let mut rec = RunRecord::new("finetune_suite");
    let mut rows = Vec::new();
    for task in &tasks {
        let mut row = vec![task.clone()];
        for opt in &optimizers {
            let mut c = TrainConfig::preset(&preset, task, opt);
            c.steps = steps;
            c.eta = eta;
            c.eval_every = (steps / 4).max(1);
            c.log_every = (steps / 8).max(1);
            // exotic baselines require the composed engine
            if !matches!(opt.as_str(), "conmezo" | "mezo" | "mezo_momentum" | "sgd" | "adamw") {
                c.mode = Mode::Composed;
            }
            let summary = Trainer::new(&rt, c)?.run()?;
            row.push(format!("{:.3} ({:.0} st/s)", summary.final_accuracy, summary.steps_per_sec));
            rec.row(vec![
                ("task", Json::str(task.as_str())),
                ("optimizer", Json::str(opt.as_str())),
                ("accuracy", Json::num(summary.final_accuracy)),
                ("steps_per_sec", Json::num(summary.steps_per_sec)),
            ]);
        }
        rows.push(row);
    }
    let mut headers = vec!["task".to_string()];
    headers.extend(optimizers.iter().cloned());
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    println!("\n{}", render_table(&h, &rows));
    let path = rec.save()?;
    println!("record: {}", path.display());
    Ok(())
}
