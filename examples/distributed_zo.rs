//! Distributed shared-randomness ZO training demo.
//!
//! Runs a 4-replica LocalCluster on the transformer objective (in-process —
//! PJRT handles are single-threaded here; the TCP path is exercised by
//! `conmezo leader` / `conmezo worker` across processes) and demonstrates
//! the two systems claims:
//!   1. wire traffic is O(1) bytes/step/worker, independent of d;
//!   2. replicas stay bit-identical without exchanging parameters.
//!
//!   cargo run --release --example distributed_zo

use conmezo::util::error::Result;
use conmezo::coordinator::{model_workers_shared, DistHypers, Evaluator, LocalCluster};
use conmezo::data::{spec, TaskGen, TrainSampler};
use conmezo::objective::BatchSource;
use conmezo::optimizer::BetaSchedule;
use conmezo::runtime::{lit_vec_f32, Arg, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::open_default()?;
    let preset = "nano";
    let task = "sst2";
    let n_workers = 4u32;
    let steps = 1500u64;
    let seed = 42u64;

    let meta = rt.preset(preset)?.clone();
    let gen = TaskGen::new(spec(task).unwrap(), meta.vocab, meta.seq_len);
    let init = rt.load_kind(preset, "init")?;
    let x0 = lit_vec_f32(&init.call(&[Arg::I32(seed as i32)])?[0])?;
    println!(
        "distributed ZO: {n_workers} replicas, d = {} ({} KiB of parameters each)",
        meta.d_raw,
        meta.d_pad * 4 / 1024
    );

    // each worker gets a private data shard (its own sampler stream) and a
    // full parameter replica, while all replicas in this process share ONE
    // bound two_point session (one forward scratch, one WorkerPool); eval
    // is sharded too
    let samplers: Vec<Box<dyn BatchSource>> = (0..n_workers)
        .map(|id| {
            let train = gen.dataset(512, seed);
            Box::new(TrainSampler::new(train, meta.batch, meta.seq_len, seed, id as u64))
                as Box<dyn BatchSource>
        })
        .collect();
    let mut workers = model_workers_shared(&rt, preset, &x0, samplers)?;
    for (id, w) in workers.iter_mut().enumerate() {
        let shard = gen.dataset(32, seed ^ 0xE0 ^ id as u64);
        let evaluator = Evaluator::new(&rt, preset, shard)?;
        w.eval_fn = Some(Box::new(move |x: &[f32]| match evaluator.evaluate(x) {
            Ok(r) => (r.correct as u64, r.total as u64),
            Err(_) => (0, 0),
        }));
    }

    let mut cluster = LocalCluster::new(workers, seed);
    let hypers = DistHypers { theta: 1.35, eta: 3e-4, lam: 1e-3 };
    let beta = BetaSchedule::PaperWarmup { beta_final: 0.99, total_steps: steps as usize };
    let summary = cluster.run(steps, hypers, &beta, steps / 4)?;

    println!("\nglobal loss curve (mean over replicas):");
    for (t, l) in summary.loss_curve.iter().step_by(summary.loss_curve.len() / 8 + 1) {
        println!("  {t:>5}  {l:.4}");
    }
    println!("\nsharded eval accuracy:");
    for (t, a) in &summary.eval_curve {
        println!("  {t:>5}  {a:.3}");
    }
    let per_step_worker = summary.wire_bytes as f64 / steps as f64 / n_workers as f64;
    let allreduce_bytes = (meta.d_pad * 4) as f64;
    println!(
        "\nwire traffic: {per_step_worker:.0} B/step/worker vs {allreduce_bytes:.0} B for a \
         gradient all-reduce -> {:.0}x reduction",
        allreduce_bytes / per_step_worker
    );
    assert!(cluster.replicas_identical(), "replicas diverged!");
    println!("replicas bit-identical after {steps} steps: OK");
    Ok(())
}
