//! END-TO-END driver (mandated by DESIGN.md): proves all layers compose on
//! a real small workload.
//!
//! Pipeline:
//!   1. PRETRAIN a transformer LM on the synthetic multi-task corpus with
//!      the AdamW step program (native reverse-mode autograd by default,
//!      build-time jax backprop on pjrt), logging the LM loss curve — this
//!      is the "pretrained model" of the paper's few-shot regime (labels
//!      corrupted 30% to leave headroom);
//!   2. ZO-FINETUNE it on a downstream task with MeZO and ConMeZO via the
//!      fused L1/L2 step programs (Pallas cone/update kernels inside);
//!   3. report the loss/accuracy curves and the iterations-to-target ratio
//!      (the paper's headline 2x claim).
//!
//!   cargo run --release --example e2e_pretrain_finetune -- [preset] [steps]
//!
//! Defaults: preset=tiny (169K params), 3000 ZO steps. With `medium`
//! (6.5M params) the same driver exercises the multi-million-parameter
//! path (slower; see EXPERIMENTS.md for a recorded run).

use conmezo::util::error::Result;
use conmezo::coordinator::{pretrain, RunRecord, TrainConfig, Trainer};
use conmezo::runtime::Runtime;
use conmezo::util::json::Json;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = args.first().map(String::as_str).unwrap_or("tiny").to_string();
    let zo_steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3000);
    let task = "sst2";
    let rt = Runtime::open_default()?;
    let mut rec = RunRecord::new("e2e_pretrain_finetune");
    rec.meta_str("preset", &preset).meta_str("task", task).meta_num("zo_steps", zo_steps as f64);

    // --- phase 1: pretrain ------------------------------------------------
    let ckpt = std::path::PathBuf::from(format!("results/e2e_pretrained_{preset}.ckpt"));
    println!("[1/3] pretraining {preset} on the mixed synthetic corpus (AdamW, 30% label noise)");
    let pt_steps = if preset == "medium" { 150 } else { 500 };
    let curve = pretrain(&rt, &preset, pt_steps, 1e-3, 0.3, 7, &ckpt)?;
    for (t, l) in &curve {
        rec.row(vec![
            ("phase", Json::str("pretrain")),
            ("step", Json::num(*t as f64)),
            ("lm_loss", Json::num(*l)),
        ]);
    }
    println!(
        "      LM loss {:.3} -> {:.3} over {pt_steps} steps",
        curve.first().map(|x| x.1).unwrap_or(f64::NAN),
        curve.last().map(|x| x.1).unwrap_or(f64::NAN)
    );

    // --- phase 2: ZO finetune (MeZO baseline, then ConMeZO) ---------------
    let mut results = Vec::new();
    for opt in ["mezo", "conmezo"] {
        println!("[2/3] finetuning on {task}-sim with {opt} ({zo_steps} steps)");
        let mut cfg = TrainConfig::preset(&preset, task, opt);
        cfg.steps = zo_steps;
        cfg.eta = 3e-4;
        cfg.eval_every = (zo_steps / 10).max(1);
        cfg.log_every = (zo_steps / 10).max(1);
        cfg.init_from = Some(ckpt.clone());
        let summary = Trainer::new(&rt, cfg)?.run()?;
        println!(
            "      {opt}: final loss {:.4}, accuracy {:.3}, {:.1} steps/s",
            summary.final_loss, summary.final_accuracy, summary.steps_per_sec
        );
        for (t, l) in &summary.loss_curve {
            rec.row(vec![
                ("phase", Json::str(opt)),
                ("step", Json::num(*t as f64)),
                ("loss", Json::num(*l)),
            ]);
        }
        for (t, a) in &summary.eval_curve {
            rec.row(vec![
                ("phase", Json::str(opt)),
                ("step", Json::num(*t as f64)),
                ("acc", Json::num(*a)),
            ]);
        }
        results.push((opt, summary));
    }

    // --- phase 3: headline readout -----------------------------------------
    println!("[3/3] headline: iterations for ConMeZO to reach MeZO's final accuracy");
    let mezo_final = results[0].1.final_accuracy;
    let con = &results[1].1;
    match con.eval_curve.iter().find(|(_, a)| *a >= mezo_final) {
        Some((step, _)) => {
            let speedup = zo_steps as f64 / *step as f64;
            println!(
                "      ConMeZO hit {mezo_final:.3} at step {step}/{zo_steps} -> {speedup:.2}x fewer iterations (paper: ~2x)"
            );
            rec.meta_num("speedup", speedup);
        }
        None => println!("      ConMeZO did not reach MeZO's final accuracy in this horizon"),
    }
    let path = rec.save()?;
    println!("record: {}", path.display());
    Ok(())
}
