#!/usr/bin/env bash
# Multi-tenant serving smoke run.
#
# Serves 4 tenants over ONE shared nano base buffer through `conmezo
# serve`: two conmezo adapter trainers (alpha evals periodically, gamma
# checkpoints + drops all live state mid-run via pause_at and resumes from
# its CMZ1 file), one mezo_momentum trainer on rte, and one eval-only
# tenant. The workload then re-runs with a different round-robin quantum.
#
# PASS iff both runs complete, gamma reports exactly one checkpoint and
# one resume (and its CMZ1 file persists), the eval tenants report
# accuracies, AND every tenant's final adapter_hash is bit-identical
# across the two schedules (per-job streams are pure functions of
# (seed, t), never of the interleaving).
#
#   examples/run_serve.sh            # build if needed, then run
set -euo pipefail
cd "$(dirname "$0")/.."

WORK="$(mktemp -d)"
trap 'rm -rf "$WORK"' EXIT

BIN="${BIN:-rust/target/release/conmezo}"
if [ ! -x "$BIN" ]; then
    cargo build --release --manifest-path rust/Cargo.toml
fi

cat >"$WORK/workload.txt" <<'EOF'
# 4 tenants over one shared nano base
quantum 2
base_seed 42
tenant name=alpha opt=conmezo steps=12 seed=7 train_n=32 eval_every=6 eval_n=16
tenant name=beta opt=mezo_momentum steps=10 seed=8 train_n=32 task=rte
tenant name=gamma opt=conmezo steps=12 seed=9 train_n=32 pause_at=5
tenant name=delta mode=eval steps=2 seed=10 eval_n=16
EOF

fail() {
    echo "FAIL: $1" >&2
    for l in serve1 serve2; do
        echo "--- $l.log ---" >&2; cat "$WORK/$l.log" >&2 || true
    done
    exit 1
}

"$BIN" serve --manifest "$WORK/workload.txt" --ckpt-dir "$WORK/ckpt1" >"$WORK/serve1.log" 2>&1 \
    || fail "serve run 1 exited nonzero"

grep -q 'serve complete: 4 tenants' "$WORK/serve1.log" || fail "run 1 did not complete"
grep -q 'tenant alpha: steps=12 evals=2' "$WORK/serve1.log" || fail "alpha did not train + eval"
grep -q 'tenant beta: steps=10' "$WORK/serve1.log" || fail "beta did not finish training"
grep 'tenant gamma:' "$WORK/serve1.log" | grep -q 'checkpoints=1 resumes=1' \
    || fail "gamma did not checkpoint + resume mid-run"
grep 'tenant delta:' "$WORK/serve1.log" | grep -q 'evals=2' || fail "delta did not eval"
grep 'tenant delta:' "$WORK/serve1.log" | grep -q 'acc=[01]\.' || fail "delta reported no accuracy"
[ -s "$WORK/ckpt1/gamma.cmz1" ] || fail "gamma checkpoint file missing"

# determinism across schedules: a different quantum must yield bit-identical
# final adapters for every tenant
"$BIN" serve --manifest "$WORK/workload.txt" --ckpt-dir "$WORK/ckpt2" --quantum 5 \
    >"$WORK/serve2.log" 2>&1 || fail "serve run 2 exited nonzero"
h1=$(grep -o 'adapter_hash=[0-9a-f]*' "$WORK/serve1.log")
h2=$(grep -o 'adapter_hash=[0-9a-f]*' "$WORK/serve2.log")
[ -n "$h1" ] || fail "run 1 reported no adapter hashes"
[ "$h1" = "$h2" ] || fail "adapter hashes diverged across quanta: [$h1] vs [$h2]"

echo "PASS: 4 tenants (train+eval), gamma checkpoint/resume mid-run, schedules bit-identical"
