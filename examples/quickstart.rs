//! Quickstart: finetune the nano preset on the sst2-sim task with ConMeZO
//! and print the loss/accuracy trajectory. Runs fully offline on the native
//! backend (no Python, no artifacts). The same program can execute the AOT
//! HLO path instead: declare the `xla` dependency (see rust/Cargo.toml and
//! README "Runtime backends"), run `make artifacts`, and build with
//! `--features pjrt`.
//!
//!   cargo run --release --example quickstart

use conmezo::util::error::Result;
use conmezo::coordinator::{Mode, TrainConfig, Trainer};
use conmezo::runtime::Runtime;

fn main() -> Result<()> {
    // 1. pick a backend (native by default; pjrt when compiled in and
    //    artifacts exist — override with CONMEZO_BACKEND=native|pjrt)
    let rt = Runtime::open_default()?;
    println!("runtime platform: {}", rt.platform());

    // 2. configure a run — paper defaults (theta=1.35, beta=0.99 with the
    //    §3.4 warm-up, lambda=1e-3), scaled step count for the demo
    let mut cfg = TrainConfig::preset("nano", "sst2", "conmezo");
    cfg.steps = 2000;
    cfg.eta = 3e-4;
    cfg.eval_every = 400;
    cfg.log_every = 200;
    cfg.mode = Mode::Fused; // whole optimizer step = one backend program

    // 3. train
    let mut trainer = Trainer::new(&rt, cfg)?;
    let summary = trainer.run()?;

    // 4. inspect
    println!("\nloss curve (step, mean two-point loss):");
    for (step, loss) in &summary.loss_curve {
        println!("  {step:>5}  {loss:.4}");
    }
    println!("\neval curve (step, accuracy):");
    for (step, acc) in &summary.eval_curve {
        println!("  {step:>5}  {acc:.3}");
    }
    println!(
        "\nfinal accuracy {:.3} | {:.1} steps/s | peak state {:.2} MiB | {} forward evals",
        summary.final_accuracy, summary.steps_per_sec, summary.peak_mem_mib, summary.evals_used
    );
    Ok(())
}
